//! Whole-model simulation runtime: stage a compiled model's weights and
//! inputs into the functional machine via the artifact's [`ModelAbi`],
//! execute the *encoded* binary, read outputs back, and differentially
//! verify them against the [`crate::ir::exec::Executor`] numerical oracle.
//!
//! This closes the loop the per-kernel unit tests leave open: every address
//! the code generator emitted, every encoded instruction, and the whole
//! memory plan are exercised together, and the machine's measured cycles
//! land next to the analytic cost-model prediction — per model, not per
//! kernel. `CompileSession::verify` and the `xgenc --run`/`--verify` CLI
//! flags are thin wrappers over this module; `rust/tests/e2e_sim.rs` is the
//! conformance suite built on it.
//!
//! The one-shot entry points (`run_model`, `run_dispatch`, `verify`) are
//! kept for compatibility and now delegate to the sessioned
//! [`crate::runtime::engine`] API ([`engine::LoadedModel`]), which
//! predecodes and stages weights once and reuses the machine across
//! requests — hold a `LoadedModel` instead of calling these in a loop. The
//! staging primitives (`stage_weights`, `stage_inputs`, `read_outputs`) and
//! the synthetic-input / tolerance helpers stay here as the shared
//! substrate both layers use.

use crate::backend::memplan::ModelAbi;
use crate::ir::dtype::DType;
use crate::ir::graph::Graph;
use crate::ir::ops::OpKind;
use crate::ir::tensor::Tensor;
use crate::isa::Instr;
use crate::runtime::engine;
use crate::sim::machine::{Machine, RunStats};
use crate::sim::MachineConfig;
use crate::util::error::{Error, Result};

/// Instruction budget for whole-model runs (zoo-scale CIFAR models retire
/// tens of millions of instructions; runaway programs still trip this).
pub const MAX_INSTRET: u64 = 4_000_000_000;

/// One finished simulation: outputs plus the machine's measurements.
pub struct SimRun {
    pub outputs: Vec<Tensor>,
    pub stats: RunStats,
}

/// Per-precision differential tolerance (relative to `max(|ref|, 1)`).
/// FP32 storage is exact on both sides, so only accumulation-order and
/// reciprocal-vs-divide rounding separate machine from oracle; quantized
/// and reduced-float datapaths sit on coarser value grids that amplify the
/// reorder noise — the bound widens with the grid, down to Binary's ±alpha
/// two-level weights. Every Table 2 precision has an explicit entry so a
/// new dtype can't silently inherit a wrong bound.
pub fn tolerance(dt: DType) -> f32 {
    match dt {
        DType::F32 | DType::I32 => 1e-4,
        DType::F16 => 2e-4,
        DType::BF16 => 5e-4,
        DType::FP8 => 1e-3,
        DType::FP4 => 2e-3,
        DType::I8 => 1e-3,
        DType::I4 => 5e-3,
        DType::Binary => 1e-2,
    }
}

/// Write every weight at its ABI address (WMEM). One bulk copy per tensor:
/// the machine's slice helpers resolve the address map once per call, not
/// once per element, so staging zoo-scale weights is effectively memcpy.
pub fn stage_weights(m: &mut Machine, g: &Graph, abi: &ModelAbi) -> Result<()> {
    for sym in abi.weights() {
        let init = g.initializers.get(&sym.tensor).ok_or_else(|| {
            Error::Runtime(format!("abi weight '{}' has no initializer", sym.name))
        })?;
        m.write_f32_slice(sym.addr, &init.materialize().data)?;
    }
    Ok(())
}

/// Write the model inputs at their ABI addresses (DMEM). I32 inputs (token
/// ids) are stored as raw integers — the IR carries them as f32 values.
pub fn stage_inputs(m: &mut Machine, abi: &ModelAbi, inputs: &[Tensor]) -> Result<()> {
    let syms: Vec<_> = abi.inputs().collect();
    if syms.len() != inputs.len() {
        return Err(Error::Runtime(format!(
            "expected {} inputs, got {}",
            syms.len(),
            inputs.len()
        )));
    }
    for (sym, t) in syms.iter().zip(inputs) {
        if t.numel() > sym.numel() {
            return Err(Error::Runtime(format!(
                "input '{}': {} elements exceed the planned extent {}",
                sym.name,
                t.numel(),
                sym.numel()
            )));
        }
        if sym.dtype == DType::I32 {
            let words: Vec<u32> = t.data.iter().map(|v| *v as i32 as u32).collect();
            m.write_u32_slice(sym.addr, &words)?;
        } else {
            m.write_f32_slice(sym.addr, &t.data)?;
        }
    }
    Ok(())
}

/// Read every model output back from its ABI address.
pub fn read_outputs(m: &mut Machine, abi: &ModelAbi) -> Result<Vec<Tensor>> {
    let mut out = Vec::new();
    for sym in abi.outputs() {
        let data = m.read_f32_slice(sym.addr, sym.numel())?;
        out.push(Tensor::new(sym.dims.clone(), data));
    }
    Ok(out)
}

/// Execute a compiled model end-to-end on a fresh functional machine:
/// stage weights + inputs, run the encoded binary, read outputs.
///
/// Thin wrapper over the sessioned engine ([`crate::runtime::engine`]):
/// builds a one-shot [`engine::LoadedModel`] and serves a single request.
/// Callers that run more than once should hold a `LoadedModel` instead and
/// amortize the predecode + weight staging.
pub fn run_model(
    cfg: &MachineConfig,
    g: &Graph,
    abi: &ModelAbi,
    asm: &[Instr],
    inputs: &[Tensor],
) -> Result<SimRun> {
    let image = engine::ModelImage::from_parts(cfg, g, abi, asm)?;
    let mut lm = engine::LoadedModel::from_image(std::sync::Arc::new(image))?;
    let resp = lm.infer(&engine::InferenceRequest::new(inputs.to_vec()))?;
    Ok(SimRun { outputs: resp.outputs, stats: resp.stats })
}

/// Execute a multi-specialization image (dispatch stub + variants, see
/// `dynshape::dispatch_image`): the runtime writes the actual extents of the
/// symbolic dims at the image's dims slot, the stub selects and jumps to the
/// matching specialization. `g`/`abi` belong to the specialization the dims
/// select. Dims matching no known configuration fail fast here — never by
/// spinning the stub's trap loop through the instruction budget.
pub fn run_dispatch(
    cfg: &MachineConfig,
    image: &crate::dynshape::DispatchImage,
    dims: &[u32],
    g: &Graph,
    abi: &ModelAbi,
    inputs: &[Tensor],
) -> Result<SimRun> {
    let mut img = engine::ModelImage::from_dispatch_parts(image, g, abi)?;
    img.mach = cfg.clone();
    let mut lm = engine::LoadedModel::from_image(std::sync::Arc::new(img))?;
    let resp = lm.infer(&engine::InferenceRequest::with_dims(inputs.to_vec(), dims.to_vec()))?;
    Ok(SimRun { outputs: resp.outputs, stats: resp.stats })
}

/// Deterministic pseudo-inputs for a graph: a bounded wave in `[-1, 1]` for
/// float inputs; for I32 inputs, indices kept below the smallest gather
/// table the input feeds (so synthesized token ids never go out of range).
pub fn synth_inputs(g: &Graph, seed: u64) -> Vec<Tensor> {
    g.inputs
        .iter()
        .map(|t| {
            let info = &g.tensors[t.0];
            let dims: Vec<usize> = match &info.shape {
                Some(s) => s.0.iter().map(|d| d.upper_bound()).collect(),
                None => vec![1],
            };
            let n: usize = dims.iter().product::<usize>().max(1);
            let data: Vec<f32> = if info.dtype == DType::I32 {
                let bound = gather_bound(g, *t).unwrap_or(97).max(1);
                (0..n)
                    .map(|i| {
                        let k = (i as u64).wrapping_mul(37).wrapping_add(seed.wrapping_mul(13));
                        (k % bound as u64) as f32
                    })
                    .collect()
            } else {
                (0..n)
                    .map(|i| {
                        let k = (i as u64).wrapping_mul(13).wrapping_add(seed) % 17;
                        (k as f32 - 8.0) / 8.0
                    })
                    .collect()
            };
            Tensor::new(dims, data)
        })
        .collect()
}

/// Smallest table extent among Gather nodes indexed by tensor `t`.
fn gather_bound(g: &Graph, t: crate::ir::graph::TensorId) -> Option<usize> {
    g.nodes
        .iter()
        .filter(|n| n.op == OpKind::Gather && n.inputs.len() >= 2 && n.inputs[1] == t)
        .filter_map(|n| {
            g.tensors[n.inputs[0].0]
                .shape
                .as_ref()
                .and_then(|s| s.0.first().map(|d| d.upper_bound()))
        })
        .min()
}

/// Outcome of one differential verification run.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub model: String,
    pub precision: DType,
    /// Output elements compared.
    pub elems: usize,
    /// Worst error relative to `max(|reference|, 1)`.
    pub max_rel_err: f32,
    /// Bound applied (see [`tolerance`]).
    pub tol: f32,
    /// Machine-measured execution.
    pub measured_cycles: u64,
    pub measured_instret: u64,
    /// Analytic cost-model prediction for the same program, when available.
    pub predicted_cycles: Option<f64>,
}

impl VerifyReport {
    pub fn passed(&self) -> bool {
        self.max_rel_err <= self.tol
    }

    /// measured / predicted (the cost model's whole-model calibration error).
    pub fn cycle_ratio(&self) -> Option<f64> {
        self.predicted_cycles
            .filter(|p| *p > 0.0)
            .map(|p| self.measured_cycles as f64 / p)
    }

    pub fn summary(&self) -> String {
        let cycles_part = match self.predicted_cycles {
            Some(p) => format!(
                "{} cycles measured vs {:.0} predicted ({:.2}x)",
                self.measured_cycles,
                p,
                self.cycle_ratio().unwrap_or(0.0)
            ),
            None => format!("{} cycles measured", self.measured_cycles),
        };
        format!(
            "{} [{}]: {} output elems, max rel err {:.2e} (tol {:.0e}) — {} | {} instructions, {}",
            self.model,
            self.precision.name(),
            self.elems,
            self.max_rel_err,
            self.tol,
            if self.passed() { "PASS" } else { "FAIL" },
            self.measured_instret,
            cycles_part,
        )
    }

    pub fn into_result(self) -> Result<VerifyReport> {
        if self.passed() {
            Ok(self)
        } else {
            Err(Error::Sim(self.summary()))
        }
    }
}

/// Differential verification: run the binary on the functional machine and
/// the graph on the reference executor, compare outputs under the
/// per-precision tolerance, and report measured vs predicted cycles.
pub fn verify(
    cfg: &MachineConfig,
    g: &Graph,
    abi: &ModelAbi,
    asm: &[Instr],
    inputs: &[Tensor],
    precision: DType,
    predicted_cycles: Option<f64>,
) -> Result<VerifyReport> {
    let mut img = engine::ModelImage::from_parts(cfg, g, abi, asm)?;
    img.precision = precision;
    img.predicted_cycles = predicted_cycles;
    let mut lm = engine::LoadedModel::from_image(std::sync::Arc::new(img))?;
    lm.verify(&engine::InferenceRequest::new(inputs.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::memplan;
    use crate::codegen::graphgen::{self, Schedules};
    use crate::frontend::{model_zoo, prepare};

    fn lowered(g: &Graph) -> (MachineConfig, memplan::MemPlan, graphgen::Program) {
        let mach = MachineConfig::xgen_asic();
        let plan = memplan::plan(g, 1 << 30, 2 << 30).unwrap();
        let prog = graphgen::lower_graph(g, &mach, &plan, &Schedules::new(), DType::F32).unwrap();
        (mach, plan, prog)
    }

    #[test]
    fn mlp_runs_and_verifies_through_the_abi() {
        let g = prepare(model_zoo::mlp(&[16, 32, 8], 2)).unwrap();
        let (mach, _plan, prog) = lowered(&g);
        let inputs = synth_inputs(&g, 42);
        let r = verify(&mach, &g, &prog.abi, &prog.asm, &inputs, DType::F32, None)
            .unwrap()
            .into_result()
            .unwrap();
        assert!(r.max_rel_err <= 1e-4, "{}", r.summary());
        assert!(r.measured_cycles > 0 && r.measured_instret > 0);
        assert_eq!(r.elems, 2 * 8);
    }

    #[test]
    fn run_model_reports_stats_and_outputs() {
        let g = prepare(model_zoo::mlp(&[8, 4], 1)).unwrap();
        let (mach, _plan, prog) = lowered(&g);
        let inputs = synth_inputs(&g, 1);
        let run = run_model(&mach, &g, &prog.abi, &prog.asm, &inputs).unwrap();
        assert_eq!(run.outputs.len(), 1);
        assert_eq!(run.outputs[0].shape, vec![1, 4]);
        assert!(run.stats.instret > 0);
    }

    #[test]
    fn synth_inputs_respect_gather_bounds() {
        let g = prepare(model_zoo::bert_tiny(1, 8)).unwrap();
        let inputs = synth_inputs(&g, 7);
        assert_eq!(inputs.len(), 1);
        // bert_tiny's vocab is 1000: every synthesized id must index it.
        for v in &inputs[0].data {
            assert!(*v >= 0.0 && *v < 1000.0, "{v}");
        }
    }

    #[test]
    fn tolerance_widens_with_coarser_grids() {
        use crate::ir::dtype::DType as D;
        let ladder = [D::F32, D::F16, D::BF16, D::FP8, D::FP4, D::I4, D::Binary];
        for w in ladder.windows(2) {
            assert!(
                tolerance(w[0]) <= tolerance(w[1]),
                "{} tol > {} tol",
                w[0],
                w[1]
            );
        }
        assert_eq!(tolerance(D::I8), 1e-3);
        assert_eq!(tolerance(D::Binary), 1e-2);
    }

    #[test]
    fn wrong_input_arity_is_an_error() {
        let g = prepare(model_zoo::mlp(&[8, 4], 1)).unwrap();
        let (mach, _plan, prog) = lowered(&g);
        assert!(run_model(&mach, &g, &prog.abi, &prog.asm, &[]).is_err());
    }
}
