//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes them
//! from the compiler's hot paths. Python never runs here — the HLO text is
//! compiled by the `xla` crate's PJRT CPU client at startup and called like
//! a function.
//!
//! The PJRT path is gated behind the `pjrt` cargo feature (the `xla` crate
//! cannot be built offline); the default build exposes the same API surface
//! with artifacts reported unavailable, so every caller falls back to the
//! bit-matching pure-rust backends.
//!
//! [`store`] is the always-available half of the runtime: persistent JSON
//! artifacts (tuning caches, bench reports) written atomically to disk.
//!
//! [`simrun`] is the whole-model simulation runtime: it stages a compiled
//! model into the functional machine through the artifact's ABI symbol
//! table, executes the encoded binary, and differentially verifies the
//! outputs against the reference executor (`CompileSession::verify`,
//! `xgenc --run`/`--verify`).

pub mod artifacts;
pub mod simrun;
pub mod store;

pub use artifacts::Artifacts;
