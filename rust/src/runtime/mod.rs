//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes them
//! from the compiler's hot paths. Python never runs here — the HLO text is
//! compiled by the `xla` crate's PJRT CPU client at startup and called like
//! a function.
//!
//! The PJRT path is gated behind the `pjrt` cargo feature (the `xla` crate
//! cannot be built offline); the default build exposes the same API surface
//! with artifacts reported unavailable, so every caller falls back to the
//! bit-matching pure-rust backends.
//!
//! [`store`] is the always-available half of the runtime: persistent JSON
//! artifacts (tuning caches, bench reports) written atomically to disk.
//!
//! [`simrun`] is the whole-model simulation runtime: it stages a compiled
//! model into the functional machine through the artifact's ABI symbol
//! table, executes the encoded binary, and differentially verifies the
//! outputs against the reference executor (`CompileSession::verify`,
//! `xgenc --run`/`--verify`).
//!
//! [`engine`] is the sessioned inference API over the same machinery:
//! [`engine::ModelImage`] (immutable, `Arc`-shared: predecoded binary +
//! specialization table) and [`engine::LoadedModel`] (one long-lived
//! machine, weights staged once, inputs re-staged per request). [`server`]
//! drives pools of `LoadedModel`s concurrently with per-model queues,
//! request batching, and backpressure; [`loadgen`] is the synthetic
//! open-loop load generator that feeds it (`xgenc serve`,
//! `benches/bench_serving.rs`).

pub mod artifacts;
pub mod engine;
pub mod loadgen;
pub mod server;
pub mod simrun;
pub mod store;

pub use artifacts::Artifacts;
pub use engine::{InferenceRequest, InferenceResponse, LoadedModel, ModelImage};
