//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes them
//! from the compiler's hot paths. Python never runs here — the HLO text is
//! compiled by the `xla` crate's PJRT CPU client at startup and called like
//! a function.

pub mod artifacts;

pub use artifacts::Artifacts;
