//! Sessioned inference engine over the functional simulator — the API the
//! serving runtime ([`crate::runtime::server`]) and the legacy
//! [`crate::runtime::simrun`] free functions are built on.
//!
//! A [`ModelImage`] is the immutable, shareable half of a deployed model:
//! the predecoded binary, the per-specialization `(dims, Graph, ModelAbi)`
//! table, and the dispatch metadata for dynamic-shape images. Build it once
//! (per model, per fleet) and hand `Arc<ModelImage>`s to as many workers as
//! you like. A [`LoadedModel`] is the mutable half: one long-lived
//! [`Machine`] bound to one image, with weights staged once at load.
//!
//! # Machine-reuse invariants
//!
//! [`LoadedModel::infer`] reuses the machine across requests instead of
//! reconstructing it, so per-request cost is staging + run. The contract:
//!
//! - **WMEM persists.** Weights are staged once by [`LoadedModel::load`] /
//!   [`LoadedModel::from_image`] and never re-staged; compiled programs
//!   treat WMEM as read-only, and for dispatch images every specialization
//!   must agree on weight placement (checked at image build).
//! - **DMEM is zeroed per request** up to the image's zero extent (the max
//!   memory-plan `dmem_peak` over specializations, plus the dims slot) —
//!   activations, scratch, and the previous request's outputs are gone.
//!   Inputs (and the dims slot, for dynamic images) are re-staged from the
//!   request.
//! - **Architectural and timing state resets.** Registers, vector state,
//!   cycle/instret counters, and the cache hierarchy (tags + LRU, not just
//!   counters) go back to power-on, so every request's outputs *and*
//!   [`RunStats`] are bit-identical to a serial run of the same request on
//!   a fresh machine — the property the serving determinism suite
//!   (`rust/tests/serving.rs`) and `benches/bench_serving.rs` assert.

use std::sync::Arc;

use crate::backend::memplan::ModelAbi;
use crate::dynshape::DispatchImage;
use crate::ir::dtype::DType;
use crate::ir::exec::Executor;
use crate::ir::graph::Graph;
use crate::ir::tensor::Tensor;
use crate::isa::encode::encode_all;
use crate::isa::Instr;
use crate::pipeline::CompiledModel;
use crate::runtime::simrun::{self, VerifyReport};
use crate::sim::machine::{Machine, RunStats};
use crate::sim::predecode::{predecode, Predecoded};
use crate::sim::MachineConfig;
use crate::util::error::{Error, Result};

/// One inference request: the model inputs, plus the actual extents of the
/// symbolic dimensions for dynamic-shape images (`None` for static models).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub inputs: Vec<Tensor>,
    pub dims: Option<Vec<u32>>,
}

impl InferenceRequest {
    pub fn new(inputs: Vec<Tensor>) -> InferenceRequest {
        InferenceRequest { inputs, dims: None }
    }

    pub fn with_dims(inputs: Vec<Tensor>, dims: Vec<u32>) -> InferenceRequest {
        InferenceRequest { inputs, dims: Some(dims) }
    }
}

/// One finished inference: outputs plus the machine's per-run measurements
/// (cycles, instret, per-class counts — all deltas for this request).
#[derive(Debug)]
pub struct InferenceResponse {
    pub outputs: Vec<Tensor>,
    pub stats: RunStats,
}

/// One specialization of a deployed model: its dim binding (empty for
/// static models), the static graph, and the ABI to stage by.
struct Spec {
    dims: Vec<u32>,
    graph: Graph,
    abi: ModelAbi,
}

/// Dynamic-shape dispatch metadata: where the runtime writes the actual dim
/// extents, and which configurations the stub knows.
struct Dispatch {
    dims_addr: u32,
    configs: Vec<Vec<u32>>,
}

/// The immutable, `Arc`-shareable half of a deployed model: predecoded
/// program + specialization table + dispatch metadata. See the module docs
/// for the reuse invariants it encodes.
pub struct ModelImage {
    pub name: String,
    pub mach: MachineConfig,
    /// Datapath precision (drives the differential-verification tolerance).
    pub precision: DType,
    /// Analytic cost-model prediction, when the compile produced one.
    pub predicted_cycles: Option<f64>,
    prog: Predecoded,
    specs: Vec<Spec>,
    dispatch: Option<Dispatch>,
    /// DMEM bytes [`Machine::reset_keep_wmem`] zeroes between requests.
    zero_extent: usize,
}

impl ModelImage {
    /// Image of one static compiled model: predecode the scheduled binary,
    /// adopt the model's machine/precision/prediction, and use the memory
    /// plan's `dmem_peak` as the per-request zero extent.
    pub fn from_compiled(c: &CompiledModel) -> Result<ModelImage> {
        let mut img = ModelImage::from_parts(&c.mach, &c.graph, c.abi(), &c.asm)?;
        img.precision = c.precision();
        img.predicted_cycles = Some(c.ppa.cycles);
        img.zero_extent = c.plan.dmem_peak as usize;
        Ok(img)
    }

    /// Image from loose parts (the legacy `simrun::run_model` tuple).
    /// Precision defaults to FP32 and the whole DMEM is zeroed per request
    /// — without a memory plan the program's footprint is unknown.
    pub fn from_parts(
        mach: &MachineConfig,
        g: &Graph,
        abi: &ModelAbi,
        asm: &[Instr],
    ) -> Result<ModelImage> {
        Ok(ModelImage {
            name: g.name.clone(),
            mach: mach.clone(),
            precision: DType::F32,
            predicted_cycles: None,
            prog: predecode(&encode_all(asm)?),
            specs: vec![Spec { dims: Vec::new(), graph: g.clone(), abi: abi.clone() }],
            dispatch: None,
            zero_extent: usize::MAX,
        })
    }

    /// Image of a multi-specialization dispatch build: the stub + variants
    /// binary plus one `(dims, graph, abi)` spec per configuration, in the
    /// image's variant order. Checks the layout contracts a reusable
    /// machine depends on: the dims slot must not overlap any staged
    /// buffer, and every specialization must place every weight at the same
    /// WMEM address (weights are staged once, from the first spec).
    pub fn from_dispatch(image: &DispatchImage, specs: &[&CompiledModel]) -> Result<ModelImage> {
        if specs.len() != image.configs.len() {
            return Err(Error::Runtime(format!(
                "dispatch image has {} configurations but {} specializations were supplied",
                image.configs.len(),
                specs.len()
            )));
        }
        let first = specs.first().ok_or_else(|| {
            Error::Runtime("dispatch image needs at least one specialization".into())
        })?;
        let weight_table = |c: &CompiledModel| -> Vec<(String, u32, u32)> {
            let mut t: Vec<_> = c
                .abi()
                .weights()
                .map(|s| (s.name.clone(), s.addr, s.bytes))
                .collect();
            t.sort();
            t
        };
        let want = weight_table(first);
        let mut zero_extent = image.dims_addr as usize + 4 * image.configs[0].len();
        for (config, c) in image.configs.iter().zip(specs) {
            if weight_table(c) != want {
                return Err(Error::Runtime(format!(
                    "specialization '{}' disagrees with '{}' on weight placement — \
                     cannot stage weights once for the whole image",
                    c.graph.name, first.graph.name
                )));
            }
            check_dims_slot(image, config, c.abi())?;
            zero_extent = zero_extent.max(c.plan.dmem_peak as usize);
        }
        let mut img = ModelImage::from_dispatch_parts(image, &first.graph, first.abi())?;
        img.name = first
            .graph
            .name
            .split('@')
            .next()
            .unwrap_or(&first.graph.name)
            .to_string();
        img.mach = first.mach.clone();
        img.precision = first.precision();
        img.zero_extent = zero_extent;
        img.specs = image
            .configs
            .iter()
            .zip(specs)
            .map(|(config, c)| Spec {
                dims: config.clone(),
                graph: c.graph.clone(),
                abi: c.abi().clone(),
            })
            .collect();
        Ok(img)
    }

    /// Dispatch image from loose parts (the legacy `simrun::run_dispatch`
    /// tuple): a single spec serves whichever configuration the request
    /// selects — the caller vouches that `g`/`abi` belong to it.
    pub fn from_dispatch_parts(
        image: &DispatchImage,
        g: &Graph,
        abi: &ModelAbi,
    ) -> Result<ModelImage> {
        for config in &image.configs {
            check_dims_slot(image, config, abi)?;
        }
        Ok(ModelImage {
            name: g.name.clone(),
            mach: MachineConfig::xgen_asic(),
            precision: DType::F32,
            predicted_cycles: None,
            prog: predecode(&image.words),
            specs: vec![Spec { dims: Vec::new(), graph: g.clone(), abi: abi.clone() }],
            dispatch: Some(Dispatch {
                dims_addr: image.dims_addr,
                configs: image.configs.clone(),
            }),
            zero_extent: usize::MAX,
        })
    }

    /// Number of specializations (1 for static models).
    pub fn spec_count(&self) -> usize {
        self.specs.len()
    }

    /// Dim binding of specialization `i` (empty for static models).
    pub fn spec_dims(&self, i: usize) -> &[u32] {
        &self.specs[i].dims
    }

    /// Deterministic synthetic request for specialization `i` — what the
    /// load generator feeds the server, and what the serial reference
    /// re-synthesizes from `(spec, seed)` to verify a served output.
    pub fn synth_request(&self, spec: usize, seed: u64) -> InferenceRequest {
        let s = &self.specs[spec];
        let inputs = simrun::synth_inputs(&s.graph, seed);
        if self.dispatch.is_some() {
            InferenceRequest::with_dims(inputs, s.dims.clone())
        } else {
            InferenceRequest::new(inputs)
        }
    }

    /// Resolve a request's dims to a specialization index, enforcing the
    /// static/dynamic contract and shape validation (unknown dims fail fast
    /// here — never by spinning the dispatch stub's trap loop).
    fn select_spec(&self, dims: Option<&[u32]>) -> Result<usize> {
        match (&self.dispatch, dims) {
            (None, None) => Ok(0),
            (None, Some(d)) => Err(Error::Runtime(format!(
                "model '{}' is static but the request carries dims {d:?}",
                self.name
            ))),
            (Some(_), None) => Err(Error::Runtime(format!(
                "model '{}' is a dynamic-shape image: the request must carry dims",
                self.name
            ))),
            (Some(disp), Some(d)) => {
                if !disp.configs.iter().any(|c| c.as_slice() == d) {
                    return Err(Error::Runtime(format!(
                        "shape validation failed: dims {d:?} match none of {} specializations",
                        disp.configs.len()
                    )));
                }
                if let Some(i) = self.specs.iter().position(|s| s.dims.as_slice() == d) {
                    Ok(i)
                } else if self.specs.len() == 1 && self.specs[0].dims.is_empty() {
                    // from_dispatch_parts: one caller-supplied spec serves
                    // whichever known configuration was requested.
                    Ok(0)
                } else {
                    Err(Error::Runtime(format!(
                        "dims {d:?} are a known configuration but no specialization carries them"
                    )))
                }
            }
        }
    }
}

/// The dims slot must not overlap any staged DMEM buffer — overlap would
/// silently corrupt inputs/activations, not fail.
fn check_dims_slot(image: &DispatchImage, dims: &[u32], abi: &ModelAbi) -> Result<()> {
    let dims_end = image.dims_addr as u64 + 4 * dims.len() as u64;
    for sym in &abi.symbols {
        let apart = sym.addr as u64 + sym.bytes as u64 <= image.dims_addr as u64
            || dims_end <= sym.addr as u64;
        if !apart {
            return Err(Error::Runtime(format!(
                "dims slot {:#x} overlaps abi symbol '{}'",
                image.dims_addr, sym.name
            )));
        }
    }
    Ok(())
}

/// The mutable half of a deployed model: one long-lived [`Machine`] bound
/// to one [`ModelImage`], weights staged once at construction. `infer` is
/// `&mut self`: a `LoadedModel` serves one request at a time — concurrency
/// comes from many `LoadedModel`s sharing one `Arc<ModelImage>` (what the
/// serving worker pool does).
pub struct LoadedModel {
    image: Arc<ModelImage>,
    machine: Machine,
    /// Whether the machine has run since the last reset (fresh machines
    /// skip the reset — keeps single-shot `run_model` on the historical
    /// cost profile).
    dirty: bool,
    /// Machine rebuilds after machine-scoped failures (see [`Self::rebuild`]).
    rebuilds: u64,
}

/// A fresh machine bound to `image` with weights staged once — the one
/// construction path shared by initial load and post-failure rebuild.
fn fresh_machine(image: &ModelImage) -> Result<Machine> {
    let mut machine = Machine::new(image.mach.clone());
    machine.max_instret = simrun::MAX_INSTRET;
    let spec = &image.specs[0];
    simrun::stage_weights(&mut machine, &spec.graph, &spec.abi)?;
    Ok(machine)
}

impl LoadedModel {
    /// Load one compiled model: build its image and bind a machine to it.
    pub fn load(c: &CompiledModel) -> Result<LoadedModel> {
        LoadedModel::from_image(Arc::new(ModelImage::from_compiled(c)?))
    }

    /// Bind a fresh machine to a shared image and stage weights once.
    pub fn from_image(image: Arc<ModelImage>) -> Result<LoadedModel> {
        let machine = fresh_machine(&image)?;
        Ok(LoadedModel { image, machine, dirty: false, rebuilds: 0 })
    }

    pub fn image(&self) -> &Arc<ModelImage> {
        &self.image
    }

    /// Recover from a machine-scoped failure (trap, caught panic, injected
    /// fault): discard the suspect machine — its DMEM, *WMEM*, registers,
    /// and caches may all be corrupted — and rebuild from the immutable
    /// image exactly as [`Self::from_image`] did. The PR 6 reuse invariant
    /// then guarantees subsequent requests are bit-identical to a
    /// fresh-machine run.
    pub fn rebuild(&mut self) -> Result<()> {
        self.machine = fresh_machine(&self.image)?;
        self.dirty = false;
        self.rebuilds += 1;
        Ok(())
    }

    /// Machine rebuilds performed so far.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Arm a one-shot fault schedule on the underlying machine: the next
    /// [`Self::infer`] consumes it (fault-injection harness / chaos mode).
    pub fn arm_faults(&mut self, plan: crate::sim::fault::FaultPlan) {
        self.machine.arm_faults(plan);
    }

    /// Serve one request: reset the machine (keeping staged weights), stage
    /// the request's inputs (and dims, for dynamic images), run the
    /// predecoded program, read outputs back. Bit-identical — outputs and
    /// stats — to running the same request on a fresh machine.
    pub fn infer(&mut self, req: &InferenceRequest) -> Result<InferenceResponse> {
        let spec_idx = self.image.select_spec(req.dims.as_deref())?;
        if self.dirty {
            self.machine.reset_keep_wmem(self.image.zero_extent);
        }
        // Dirty from here: even a failed staging leaves partial writes.
        self.dirty = true;
        let spec = &self.image.specs[spec_idx];
        simrun::stage_inputs(&mut self.machine, &spec.abi, &req.inputs)?;
        if let Some(disp) = &self.image.dispatch {
            let dims = req.dims.as_deref().unwrap_or_default();
            self.machine.write_u32_slice(disp.dims_addr, dims)?;
        }
        let stats = self.machine.run_predecoded(&self.image.prog)?;
        let outputs = simrun::read_outputs(&mut self.machine, &spec.abi)?;
        Ok(InferenceResponse { outputs, stats })
    }

    /// Differential verification of one request: serve it, run the same
    /// inputs through the reference executor, and compare under the
    /// image's per-precision tolerance.
    pub fn verify(&mut self, req: &InferenceRequest) -> Result<VerifyReport> {
        let resp = self.infer(req)?;
        let spec = &self.image.specs[self.image.select_spec(req.dims.as_deref())?];
        let want = Executor::new().run(&spec.graph, &req.inputs)?;
        if want.len() != resp.outputs.len() {
            return Err(Error::Sim(format!(
                "output arity mismatch: machine {} vs reference {}",
                resp.outputs.len(),
                want.len()
            )));
        }
        let mut max_rel_err = 0.0f32;
        let mut elems = 0usize;
        for (got, want_t) in resp.outputs.iter().zip(&want) {
            if got.numel() < want_t.numel() {
                return Err(Error::Sim(format!(
                    "output size mismatch: machine {} vs reference {}",
                    got.numel(),
                    want_t.numel()
                )));
            }
            for (a, b) in got.data.iter().zip(&want_t.data) {
                if !a.is_finite() || !b.is_finite() {
                    return Err(Error::Sim(format!("non-finite output: {a} vs {b}")));
                }
                max_rel_err = max_rel_err.max((a - b).abs() / b.abs().max(1.0));
                elems += 1;
            }
        }
        Ok(VerifyReport {
            model: spec.graph.name.clone(),
            precision: self.image.precision,
            elems,
            max_rel_err,
            tol: simrun::tolerance(self.image.precision),
            measured_cycles: resp.stats.cycles,
            measured_instret: resp.stats.instret,
            predicted_cycles: self.image.predicted_cycles,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{model_zoo, prepare};
    use crate::pipeline::{CompileOptions, CompileSession};

    fn compiled(precision: DType) -> CompiledModel {
        let g = prepare(model_zoo::mlp(&[32, 16, 8], 1)).unwrap();
        let mut s = CompileSession::new(CompileOptions { precision, ..Default::default() });
        s.compile(&g).unwrap()
    }

    fn bits(outs: &[Tensor]) -> Vec<Vec<u32>> {
        outs.iter()
            .map(|t| t.data.iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    #[test]
    fn machine_reuse_is_bit_identical_to_fresh() {
        let c = compiled(DType::F32);
        let mut lm = LoadedModel::load(&c).unwrap();
        for seed in [3u64, 4, 5] {
            let req = InferenceRequest::new(simrun::synth_inputs(&c.graph, seed));
            let served = lm.infer(&req).unwrap();
            // Fresh-machine serial reference for the same request.
            let fresh = simrun::run_model(&c.mach, &c.graph, c.abi(), &c.asm, &req.inputs).unwrap();
            assert_eq!(bits(&served.outputs), bits(&fresh.outputs), "seed {seed}");
            assert_eq!(served.stats, fresh.stats, "seed {seed}: timing must reset too");
        }
    }

    #[test]
    fn quantized_model_reuse_stays_in_tolerance() {
        let c = compiled(DType::I8);
        let mut lm = LoadedModel::load(&c).unwrap();
        for seed in [1u64, 2] {
            let req = InferenceRequest::new(simrun::synth_inputs(&c.graph, seed));
            let r = lm.verify(&req).unwrap();
            assert!(r.passed(), "seed {seed}: {}", r.summary());
            assert_eq!(r.precision, DType::I8);
        }
    }

    #[test]
    fn static_model_rejects_dims_and_dynamic_requires_them() {
        let c = compiled(DType::F32);
        let mut lm = LoadedModel::load(&c).unwrap();
        let inputs = simrun::synth_inputs(&c.graph, 1);
        let err = lm.infer(&InferenceRequest::with_dims(inputs, vec![1])).unwrap_err();
        assert!(err.to_string().contains("static"), "{err}");
    }

    #[test]
    fn verify_carries_compile_metadata() {
        let c = compiled(DType::F32);
        let mut lm = LoadedModel::load(&c).unwrap();
        let r = lm.verify(&InferenceRequest::new(simrun::synth_inputs(&c.graph, 42))).unwrap();
        assert!(r.passed(), "{}", r.summary());
        assert!(r.predicted_cycles.unwrap() > 0.0);
        assert!(r.cycle_ratio().unwrap() > 0.0);
    }
}
