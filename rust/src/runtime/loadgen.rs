//! Synthetic load generation for the serving runtime: an open-loop
//! Poisson-process arrival stream (or a closed-loop saturation stream) of
//! deterministic requests over a mixed model fleet, plus the demo fleet
//! `xgenc serve` and `benches/bench_serving.rs` share.
//!
//! Determinism is the point: every request is reconstructible from
//! `(model, spec, request_seed(seed, i))`, so a sampled served output can
//! be re-synthesized and replayed through the serial engine
//! ([`DemoFleet::reference`]) and compared bit-for-bit — outputs *and*
//! per-run [`RunStats`] — against what the concurrent server returned.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dynshape::{self, DispatchImage};
use crate::frontend::{model_zoo, prepare};
use crate::ir::dtype::DType;
use crate::pipeline::{CompileOptions, CompiledModel};
use crate::runtime::engine::ModelImage;
use crate::runtime::server::{Server, Ticket};
use crate::runtime::simrun::{self, SimRun};
use crate::sim::machine::RunStats;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One entry of the traffic mix: a model index and its relative weight.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub model: usize,
    pub weight: f64,
}

/// Load-generator knobs (`xgenc serve`/`loadgen` flags map onto these).
#[derive(Debug, Clone)]
pub struct LoadGenOptions {
    /// Requests to generate.
    pub requests: u64,
    /// Mean arrivals per second; 0 = closed-loop saturation (blocking
    /// submit, no pacing).
    pub rate: f64,
    /// Seed for arrivals, the model/spec mix, and per-request inputs.
    pub seed: u64,
    /// Keep every Nth response for offline verification (0 = never).
    pub sample_every: u64,
    /// Stop generating after this long even if `requests` remain.
    pub duration: Option<Duration>,
}

impl Default for LoadGenOptions {
    fn default() -> LoadGenOptions {
        LoadGenOptions { requests: 1000, rate: 0.0, seed: 42, sample_every: 0, duration: None }
    }
}

/// The seed of generated request `i` under generator seed `seed` — public
/// so verifiers can re-synthesize any sampled request.
pub fn request_seed(seed: u64, i: u64) -> u64 {
    seed ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Weighted model pick — one `rng.f64()` draw per request, shared by the
/// concurrent driver and the serial baseline so both generate the same
/// stream from the same seed.
pub fn pick_model(rng: &mut Rng, mix: &[MixEntry]) -> usize {
    let total: f64 = mix.iter().map(|m| m.weight).sum();
    let mut pick = rng.f64() * total;
    for m in mix {
        if pick < m.weight {
            return m.model;
        }
        pick -= m.weight;
    }
    mix[mix.len() - 1].model
}

/// A retained response: enough to re-synthesize the request (`model`,
/// `spec`, `seed`) and the served result to compare against.
pub struct Sample {
    pub model: usize,
    pub spec: usize,
    pub seed: u64,
    pub output_bits: Vec<Vec<u32>>,
    pub stats: RunStats,
}

/// What one load-generation run produced.
pub struct LoadReport {
    /// Requests generated (= accepted + shed at submit).
    pub generated: u64,
    pub accepted: u64,
    /// Shed synchronously by `submit` (queue full).
    pub shed_submit: u64,
    /// Completed successfully.
    pub ok: u64,
    /// Shed synchronously by `submit` because the model was quarantined by
    /// its circuit breaker.
    pub shed_quarantine: u64,
    /// Shed by a worker after queueing past the deadline.
    pub shed_deadline: u64,
    /// Completed with a machine-scoped error (trap or worker panic that
    /// survived every retry) — the chaos-mode unavailability signal.
    pub failed_machine: u64,
    /// Completed with any other error (always 0 in a healthy run).
    pub failed: u64,
    pub duration_s: f64,
    pub samples: Vec<Sample>,
}

impl LoadReport {
    pub fn offered_rps(&self) -> f64 {
        self.generated as f64 / self.duration_s.max(1e-9)
    }

    /// Fraction of *completed* (non-shed) requests that were served
    /// successfully — sheds are backpressure, not unavailability; a typed
    /// failure after retries is. 1.0 when nothing completed.
    pub fn availability(&self) -> f64 {
        let completed = self.ok + self.failed + self.failed_machine;
        if completed == 0 {
            1.0
        } else {
            self.ok as f64 / completed as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} generated in {:.2}s ({:.0} req/s offered): {} ok, {} shed at submit, \
             {} shed quarantined, {} shed at deadline, {} failed machine-scoped, \
             {} failed, {:.4} availability, {} sampled",
            self.generated,
            self.duration_s,
            self.offered_rps(),
            self.ok,
            self.shed_submit,
            self.shed_quarantine,
            self.shed_deadline,
            self.failed_machine,
            self.failed,
            self.availability(),
            self.samples.len(),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("generated", Json::Num(self.generated as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("shed_submit", Json::Num(self.shed_submit as f64)),
            ("shed_quarantine", Json::Num(self.shed_quarantine as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed_deadline", Json::Num(self.shed_deadline as f64)),
            ("failed_machine", Json::Num(self.failed_machine as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("availability", Json::Num(self.availability())),
            ("duration_s", Json::Num(self.duration_s)),
            ("offered_rps", Json::Num(self.offered_rps())),
            ("samples", Json::Num(self.samples.len() as f64)),
        ])
    }
}

/// Drive a running [`Server`] with synthetic traffic.
///
/// Open loop (`rate > 0`): exponential inter-arrival gaps (a Poisson
/// process at `rate` req/s) and non-blocking submits — a full queue sheds
/// the arrival, as production front-ends do. Closed loop (`rate == 0`):
/// blocking submits as fast as the server drains, measuring saturation
/// throughput.
///
/// A collector thread waits on tickets as they are issued so completed
/// responses never accumulate; the generator thread only paces, picks
/// `(model, spec, seed)`, and submits.
pub fn drive(
    server: &Server,
    images: &[Arc<ModelImage>],
    mix: &[MixEntry],
    opts: &LoadGenOptions,
) -> LoadReport {
    assert!(!mix.is_empty(), "loadgen needs a non-empty mix");
    let total_weight: f64 = mix.iter().map(|m| m.weight).sum();
    assert!(total_weight > 0.0, "loadgen mix weights must sum > 0");

    let (tx, rx) = mpsc::channel::<(Ticket, Option<(usize, usize, u64)>)>();
    let (mut generated, mut accepted, mut shed_submit, mut shed_quarantine) =
        (0u64, 0u64, 0u64, 0u64);
    let start = Instant::now();

    let (ok, shed_deadline, failed_machine, failed, samples) = std::thread::scope(|s| {
        let collector = s.spawn(move || {
            let (mut ok, mut shed_deadline, mut failed_machine, mut failed) =
                (0u64, 0u64, 0u64, 0u64);
            let mut samples = Vec::new();
            for (ticket, tag) in rx {
                match ticket.wait() {
                    Ok(out) => {
                        ok += 1;
                        if let Some((model, spec, seed)) = tag {
                            samples.push(Sample {
                                model,
                                spec,
                                seed,
                                output_bits: out
                                    .outputs
                                    .iter()
                                    .map(|t| t.data.iter().map(|v| v.to_bits()).collect())
                                    .collect(),
                                stats: out.stats,
                            });
                        }
                    }
                    Err(e) => {
                        if e.to_string().contains("deadline") {
                            shed_deadline += 1;
                        } else if e.is_machine_scoped() {
                            failed_machine += 1;
                        } else {
                            failed += 1;
                        }
                    }
                }
            }
            (ok, shed_deadline, failed_machine, failed, samples)
        });

        let mut rng = Rng::new(opts.seed);
        let mut next_at = 0.0f64;
        while generated < opts.requests {
            if let Some(d) = opts.duration {
                if start.elapsed() >= d {
                    break;
                }
            }
            if opts.rate > 0.0 {
                // Poisson process: exponential inter-arrival gaps.
                next_at += -(1.0 - rng.f64()).ln() / opts.rate;
                loop {
                    let now = start.elapsed().as_secs_f64();
                    if now >= next_at {
                        break;
                    }
                    let gap = next_at - now;
                    if gap > 200e-6 {
                        std::thread::sleep(Duration::from_secs_f64(gap - 100e-6));
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }
            let model = pick_model(&mut rng, mix);
            let spec = rng.index(images[model].spec_count());
            let seed = request_seed(opts.seed, generated);
            let req = images[model].synth_request(spec, seed);
            generated += 1;
            let tag = if opts.sample_every > 0 && generated % opts.sample_every == 0 {
                Some((model, spec, seed))
            } else {
                None
            };
            let res = if opts.rate > 0.0 {
                server.submit(model, req)
            } else {
                server.submit_blocking(model, req)
            };
            match res {
                Ok(ticket) => {
                    accepted += 1;
                    // Collector hung up only if it panicked; surface that.
                    tx.send((ticket, tag)).expect("loadgen collector died");
                }
                Err(e) => {
                    if e.to_string().contains("quarantine") {
                        shed_quarantine += 1;
                    } else {
                        shed_submit += 1;
                    }
                }
            }
        }
        drop(tx);
        collector.join().expect("loadgen collector panicked")
    });

    LoadReport {
        generated,
        accepted,
        shed_submit,
        shed_quarantine,
        ok,
        shed_deadline,
        failed_machine,
        failed,
        duration_s: start.elapsed().as_secs_f64(),
        samples,
    }
}

/// How one fleet model reproduces a served output serially.
enum Reference {
    Static(CompiledModel),
    Dynamic(DispatchImage, Vec<CompiledModel>),
}

/// The mixed demo fleet `xgenc serve` and the serving bench share: an FP32
/// MLP, the same model quantized to INT8, and a dynamic-batch MLP with
/// three specializations — plus the serial reference engine that replays
/// any `(model, spec, seed)` request for bit-exact verification.
pub struct DemoFleet {
    pub images: Vec<Arc<ModelImage>>,
    pub mix: Vec<MixEntry>,
    refs: Vec<Reference>,
}

impl DemoFleet {
    pub fn build() -> Result<DemoFleet> {
        let mut images = Vec::new();
        let mut refs = Vec::new();

        // Model 0: FP32 static MLP.
        let g = prepare(model_zoo::mlp(&[32, 16, 8], 1))?;
        let c = crate::pipeline::CompileSession::new(CompileOptions::default()).compile(&g)?;
        let mut img = ModelImage::from_compiled(&c)?;
        img.name = "mlp-f32".into();
        images.push(Arc::new(img));
        refs.push(Reference::Static(c));

        // Model 1: the same MLP quantized to INT8 (calibrated on synthetic
        // activations, like `precision_sweep`).
        let opts_i8 = CompileOptions {
            precision: DType::I8,
            calib_inputs: vec![simrun::synth_inputs(&g, 42)],
            ..Default::default()
        };
        let c = crate::pipeline::CompileSession::new(opts_i8).compile(&g)?;
        let mut img = ModelImage::from_compiled(&c)?;
        img.name = "mlp-i8".into();
        images.push(Arc::new(img));
        refs.push(Reference::Static(c));

        // Model 2: dynamic-batch MLP, specialized for batches 1/2/4.
        let gd = prepare(model_zoo::mlp_dynamic(&[16, 8, 4], 8))?;
        let configs: Vec<Vec<(String, usize)>> = [1usize, 2, 4]
            .iter()
            .map(|b| vec![("batch".to_string(), *b)])
            .collect();
        let (dimage, compiled) =
            dynshape::compile_image(&gd, &configs, &CompileOptions::default())?;
        let spec_refs: Vec<&CompiledModel> = compiled.iter().collect();
        let mut img = ModelImage::from_dispatch(&dimage, &spec_refs)?;
        img.name = "mlp-dyn".into();
        images.push(Arc::new(img));
        refs.push(Reference::Dynamic(dimage, compiled));

        // Traffic mix: mostly FP32, a quantized slice, a dynamic slice.
        let mix = vec![
            MixEntry { model: 0, weight: 0.5 },
            MixEntry { model: 1, weight: 0.3 },
            MixEntry { model: 2, weight: 0.2 },
        ];
        Ok(DemoFleet { images, mix, refs })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.images.iter().map(|i| i.name.clone()).collect()
    }

    /// Serial fresh-machine replay of the request `(model, spec, seed)`
    /// identifies — the ground truth a served [`Sample`] must match
    /// bit-for-bit, stats included.
    pub fn reference(&self, model: usize, spec: usize, seed: u64) -> Result<SimRun> {
        match &self.refs[model] {
            Reference::Static(c) => {
                let inputs = simrun::synth_inputs(&c.graph, seed);
                simrun::run_model(&c.mach, &c.graph, c.abi(), &c.asm, &inputs)
            }
            Reference::Dynamic(dimage, compiled) => {
                let c = &compiled[spec];
                let dims = self.images[model].spec_dims(spec).to_vec();
                let inputs = simrun::synth_inputs(&c.graph, seed);
                simrun::run_dispatch(&c.mach, dimage, &dims, &c.graph, c.abi(), &inputs)
            }
        }
    }

    /// True when a [`Sample`] matches its serial reference bit-for-bit.
    pub fn sample_matches(&self, sample: &Sample) -> Result<bool> {
        let want = self.reference(sample.model, sample.spec, sample.seed)?;
        let want_bits: Vec<Vec<u32>> = want
            .outputs
            .iter()
            .map(|t| t.data.iter().map(|v| v.to_bits()).collect())
            .collect();
        Ok(want_bits == sample.output_bits && want.stats == sample.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::server::ServerOptions;

    #[test]
    fn request_seed_is_stable_and_distinct() {
        assert_eq!(request_seed(42, 0), request_seed(42, 0));
        assert_ne!(request_seed(42, 0), request_seed(42, 1));
        assert_ne!(request_seed(42, 0), request_seed(43, 0));
    }

    #[test]
    fn saturation_drive_serves_everything_and_samples_verify() {
        let fleet = DemoFleet::build().unwrap();
        let server = Server::start(
            &fleet.images,
            ServerOptions { workers: 2, max_batch: 4, queue_depth: 16, ..Default::default() },
        )
        .unwrap();
        let report = drive(
            &server,
            &fleet.images,
            &fleet.mix,
            &LoadGenOptions { requests: 24, rate: 0.0, seed: 7, sample_every: 6, duration: None },
        );
        let sreport = server.shutdown();
        assert_eq!(report.generated, 24);
        assert_eq!(report.ok, 24, "saturation mode must not shed: {}", report.summary());
        assert_eq!(report.failed, 0);
        assert_eq!(sreport.served, 24);
        assert_eq!(report.samples.len(), 4);
        for s in &report.samples {
            assert!(
                fleet.sample_matches(s).unwrap(),
                "sample (model {}, spec {}, seed {}) diverged",
                s.model,
                s.spec,
                s.seed
            );
        }
    }

    #[test]
    fn open_loop_paces_against_the_clock() {
        let fleet = DemoFleet::build().unwrap();
        let server = Server::start(
            &fleet.images,
            ServerOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        // 20 arrivals at 2 kHz should take ~10 ms of pacing.
        let report = drive(
            &server,
            &fleet.images,
            &fleet.mix,
            &LoadGenOptions {
                requests: 20,
                rate: 2000.0,
                seed: 3,
                sample_every: 0,
                duration: None,
            },
        );
        server.shutdown();
        assert_eq!(report.generated, 20);
        assert_eq!(report.ok + report.shed_submit + report.shed_deadline, 20);
        assert!(report.duration_s > 0.0);
    }
}
