//! Batched concurrent inference server over the fast simulator — the
//! production serving runtime.
//!
//! [`Server::start`] loads a fleet of [`ModelImage`]s and spawns a pool of
//! worker threads; every worker owns one long-lived [`LoadedModel`] per
//! model (predecode + weight staging happen once per worker × model; the
//! per-request cost is the `reset_keep_wmem` path: zero the live DMEM
//! extent, re-stage inputs, run). Requests flow through bounded per-model
//! queues:
//!
//! - **Batching:** a worker drains up to `max_batch` *compatible* requests
//!   (same model, same dims — dynamic-shape images batch per
//!   specialization) in one dequeue, amortizing lock traffic and keeping
//!   the machine's working set hot across the batch.
//! - **Backpressure:** [`Server::submit`] sheds with an error once a
//!   model's queue holds `queue_depth` requests (open-loop callers);
//!   [`Server::submit_blocking`] waits for space instead (closed-loop
//!   saturation drivers). With a `deadline`, requests that queued longer
//!   than the budget are shed *at dequeue* with an error — the server
//!   returns a late error, never a wrong answer.
//! - **Determinism:** workers add no numerical or timing state of their
//!   own; every served output and its [`RunStats`] are bit-identical to a
//!   serial [`LoadedModel::infer`] of the same request
//!   (`rust/tests/serving.rs` proves it under concurrency).
//!
//! [`Server::shutdown`] closes the queues, drains what's enqueued, joins
//! the pool, and returns a [`ServerReport`]: throughput (req/s and
//! simulated MIPS), latency percentiles, batching efficiency, queue-depth
//! and shed accounting — what `benches/bench_serving.rs` emits as
//! `BENCH_serving.json`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ir::tensor::Tensor;
use crate::runtime::engine::{InferenceRequest, LoadedModel, ModelImage};
use crate::sim::machine::RunStats;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::stats::percentile;

/// Server tuning knobs (`xgenc serve` flags map 1:1 onto these).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Worker threads (0 = one per available core).
    pub workers: usize,
    /// Max compatible requests drained per dequeue (min 1).
    pub max_batch: usize,
    /// Per-model queue bound before `submit` sheds (min 1).
    pub queue_depth: usize,
    /// Shed requests that queued longer than this before dispatch.
    pub deadline: Option<Duration>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions { workers: 0, max_batch: 8, queue_depth: 256, deadline: None }
    }
}

/// One served request: which model ran, its outputs and per-run machine
/// stats, and the enqueue → completion latency.
#[derive(Debug)]
pub struct ServedOutput {
    pub model: usize,
    pub outputs: Vec<Tensor>,
    pub stats: RunStats,
    pub latency: Duration,
}

/// One-shot response slot a worker fills and a [`Ticket`] waits on.
struct Slot {
    result: Mutex<Option<Result<ServedOutput>>>,
    done: Condvar,
}

fn fill(slot: &Slot, out: Result<ServedOutput>) {
    let mut r = slot.result.lock().unwrap();
    *r = Some(out);
    slot.done.notify_all();
}

/// Handle to one submitted request; [`Ticket::wait`] blocks until a worker
/// serves or sheds it.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    pub fn wait(self) -> Result<ServedOutput> {
        let mut r = self.slot.result.lock().unwrap();
        loop {
            if let Some(out) = r.take() {
                return out;
            }
            r = self.slot.done.wait(r).unwrap();
        }
    }
}

struct Pending {
    model: usize,
    req: InferenceRequest,
    enqueued: Instant,
    slot: Arc<Slot>,
}

/// Everything behind the server mutex: the per-model queues plus the
/// submit-side counters maintained under the same lock.
struct State {
    queues: Vec<VecDeque<Pending>>,
    open: bool,
    submitted: u64,
    shed_queue_full: u64,
    depth_samples: u64,
    depth_sum: u64,
    depth_max: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Signaled on enqueue and shutdown (workers wait here).
    work: Condvar,
    /// Signaled on dequeue (blocking submitters wait here).
    space: Condvar,
    opts: ServerOptions,
}

/// Per-worker accounting, merged at shutdown.
#[derive(Default)]
struct WorkerStats {
    served: u64,
    shed_deadline: u64,
    batches: u64,
    batched_requests: u64,
    max_batch_seen: usize,
    latencies_ms: Vec<f64>,
    cycles: u64,
    instret: u64,
    per_model_served: Vec<u64>,
}

/// The running server. Always finish with [`Server::shutdown`]; dropping
/// the handle without it would leave the worker threads parked forever.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<WorkerStats>>,
    started: Instant,
}

impl Server {
    /// Load the fleet and spawn the worker pool. Every worker stages every
    /// model's weights into its own machines up front — startup cost paid
    /// once, and load errors surface here rather than inside a thread.
    pub fn start(images: &[Arc<ModelImage>], opts: ServerOptions) -> Result<Server> {
        if images.is_empty() {
            return Err(Error::Runtime("server needs at least one model".into()));
        }
        let opts = ServerOptions {
            workers: crate::util::resolve_workers(opts.workers),
            max_batch: opts.max_batch.max(1),
            queue_depth: opts.queue_depth.max(1),
            deadline: opts.deadline,
        };
        let mut fleets: Vec<Vec<LoadedModel>> = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let mut fleet = Vec::with_capacity(images.len());
            for img in images {
                fleet.push(LoadedModel::from_image(img.clone())?);
            }
            fleets.push(fleet);
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queues: images.iter().map(|_| VecDeque::new()).collect(),
                open: true,
                submitted: 0,
                shed_queue_full: 0,
                depth_samples: 0,
                depth_sum: 0,
                depth_max: 0,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            opts,
        });
        let handles = fleets
            .into_iter()
            .enumerate()
            .map(|(w, fleet)| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, fleet, w))
            })
            .collect();
        Ok(Server { shared, handles, started: Instant::now() })
    }

    pub fn model_count(&self) -> usize {
        self.shared.state.lock().unwrap().queues.len()
    }

    /// Enqueue a request; sheds with an error when the model's queue is
    /// full (graceful backpressure for open-loop arrivals).
    pub fn submit(&self, model: usize, req: InferenceRequest) -> Result<Ticket> {
        self.enqueue(model, req, false)
    }

    /// Enqueue a request, waiting for queue space instead of shedding —
    /// the closed-loop saturation driver.
    pub fn submit_blocking(&self, model: usize, req: InferenceRequest) -> Result<Ticket> {
        self.enqueue(model, req, true)
    }

    fn enqueue(&self, model: usize, req: InferenceRequest, block: bool) -> Result<Ticket> {
        let shared = &self.shared;
        let mut st = shared.state.lock().unwrap();
        if model >= st.queues.len() {
            return Err(Error::Runtime(format!(
                "unknown model index {model} (fleet has {})",
                st.queues.len()
            )));
        }
        if block {
            while st.open && st.queues[model].len() >= shared.opts.queue_depth {
                st = shared.space.wait(st).unwrap();
            }
        }
        if !st.open {
            return Err(Error::Runtime("server is shut down".into()));
        }
        if st.queues[model].len() >= shared.opts.queue_depth {
            st.shed_queue_full += 1;
            return Err(Error::Runtime(format!(
                "shed: queue full for model {model} ({} pending)",
                st.queues[model].len()
            )));
        }
        let slot = Arc::new(Slot { result: Mutex::new(None), done: Condvar::new() });
        st.queues[model].push_back(Pending {
            model,
            req,
            enqueued: Instant::now(),
            slot: Arc::clone(&slot),
        });
        st.submitted += 1;
        let depth = st.queues[model].len();
        st.depth_samples += 1;
        st.depth_sum += depth as u64;
        st.depth_max = st.depth_max.max(depth);
        drop(st);
        shared.work.notify_one();
        Ok(Ticket { slot })
    }

    /// Close the queues, let the workers drain what is already enqueued,
    /// join the pool, and return the merged report.
    pub fn shutdown(self) -> ServerReport {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        let workers = self.handles.len();
        let mut merged = WorkerStats::default();
        for h in self.handles {
            let w = h.join().expect("server worker panicked");
            merged.served += w.served;
            merged.shed_deadline += w.shed_deadline;
            merged.batches += w.batches;
            merged.batched_requests += w.batched_requests;
            merged.max_batch_seen = merged.max_batch_seen.max(w.max_batch_seen);
            merged.latencies_ms.extend(w.latencies_ms);
            merged.cycles += w.cycles;
            merged.instret += w.instret;
            if merged.per_model_served.len() < w.per_model_served.len() {
                merged.per_model_served.resize(w.per_model_served.len(), 0);
            }
            for (m, n) in w.per_model_served.iter().enumerate() {
                merged.per_model_served[m] += n;
            }
        }
        let wall_seconds = self.started.elapsed().as_secs_f64();
        let st = self.shared.state.lock().unwrap();
        ServerReport {
            workers,
            wall_seconds,
            submitted: st.submitted,
            served: merged.served,
            shed_queue_full: st.shed_queue_full,
            shed_deadline: merged.shed_deadline,
            batches: merged.batches,
            batched_requests: merged.batched_requests,
            max_batch: merged.max_batch_seen,
            total_cycles: merged.cycles,
            total_instret: merged.instret,
            per_model_served: merged.per_model_served,
            latencies_ms: merged.latencies_ms,
            mean_queue_depth: if st.depth_samples == 0 {
                0.0
            } else {
                st.depth_sum as f64 / st.depth_samples as f64
            },
            max_queue_depth: st.depth_max,
        }
    }
}

fn worker_loop(shared: &Shared, mut fleet: Vec<LoadedModel>, wid: usize) -> WorkerStats {
    let n_models = fleet.len();
    let mut stats = WorkerStats { per_model_served: vec![0; n_models], ..Default::default() };
    // Stagger starting queues across workers so a mixed fleet doesn't
    // funnel every worker onto model 0.
    let mut cursor = wid % n_models;
    loop {
        let mut batch: Vec<Pending> = Vec::new();
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                let found = (0..n_models)
                    .map(|k| (cursor + k) % n_models)
                    .find(|&qi| !st.queues[qi].is_empty());
                if let Some(qi) = found {
                    cursor = (qi + 1) % n_models;
                    let q = &mut st.queues[qi];
                    let first = q.pop_front().unwrap();
                    let dims = first.req.dims.clone();
                    batch.push(first);
                    while batch.len() < shared.opts.max_batch
                        && q.front().is_some_and(|p| p.req.dims == dims)
                    {
                        batch.push(q.pop_front().unwrap());
                    }
                    break;
                }
                if !st.open {
                    return stats;
                }
                st = shared.work.wait(st).unwrap();
            }
        }
        shared.space.notify_all();
        stats.batches += 1;
        stats.batched_requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());
        for p in batch {
            if let Some(deadline) = shared.opts.deadline {
                let waited = p.enqueued.elapsed();
                if waited > deadline {
                    stats.shed_deadline += 1;
                    fill(
                        &p.slot,
                        Err(Error::Runtime(format!(
                            "shed: deadline exceeded ({:.1} ms queued > {:.1} ms budget)",
                            waited.as_secs_f64() * 1e3,
                            deadline.as_secs_f64() * 1e3
                        ))),
                    );
                    continue;
                }
            }
            match fleet[p.model].infer(&p.req) {
                Ok(resp) => {
                    stats.served += 1;
                    stats.per_model_served[p.model] += 1;
                    stats.cycles += resp.stats.cycles;
                    stats.instret += resp.stats.instret;
                    let latency = p.enqueued.elapsed();
                    stats.latencies_ms.push(latency.as_secs_f64() * 1e3);
                    fill(
                        &p.slot,
                        Ok(ServedOutput {
                            model: p.model,
                            outputs: resp.outputs,
                            stats: resp.stats,
                            latency,
                        }),
                    );
                }
                Err(e) => fill(&p.slot, Err(e)),
            }
        }
    }
}

/// Merged serving metrics for one server lifetime.
pub struct ServerReport {
    pub workers: usize,
    pub wall_seconds: f64,
    /// Requests accepted into a queue (submit-side sheds are not counted).
    pub submitted: u64,
    pub served: u64,
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    /// Dequeue operations and the requests they carried — efficiency is
    /// `batched_requests / batches`.
    pub batches: u64,
    pub batched_requests: u64,
    /// Largest single batch observed.
    pub max_batch: usize,
    pub total_cycles: u64,
    pub total_instret: u64,
    pub per_model_served: Vec<u64>,
    /// Enqueue → completion latency of every served request, in ms.
    pub latencies_ms: Vec<f64>,
    /// Queue depth sampled at every accepted submit.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
}

impl ServerReport {
    /// Served requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.wall_seconds.max(1e-9)
    }

    /// Simulated instructions retired per wall-clock second, in millions.
    pub fn simulated_mips(&self) -> f64 {
        self.total_instret as f64 / self.wall_seconds.max(1e-9) / 1e6
    }

    /// Mean requests per dequeue (1.0 = no batching benefit).
    pub fn batching_efficiency(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Latency percentile in ms (`p` in `[0, 100]`); 0 when nothing served.
    pub fn latency_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            percentile(&self.latencies_ms, p)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} workers: {} served in {:.2}s ({:.0} req/s, {:.1} simulated MIPS) | \
             p50 {:.3} ms p99 {:.3} ms p99.9 {:.3} ms | batch {:.2} avg / {} max | \
             queue {:.1} avg / {} max | shed {} full + {} deadline",
            self.workers,
            self.served,
            self.wall_seconds,
            self.throughput_rps(),
            self.simulated_mips(),
            self.latency_ms(50.0),
            self.latency_ms(99.0),
            self.latency_ms(99.9),
            self.batching_efficiency(),
            self.max_batch,
            self.mean_queue_depth,
            self.max_queue_depth,
            self.shed_queue_full,
            self.shed_deadline,
        )
    }

    pub fn to_json(&self) -> Json {
        let per_model: Vec<f64> = self.per_model_served.iter().map(|n| *n as f64).collect();
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("submitted", Json::Num(self.submitted as f64)),
            ("served", Json::Num(self.served as f64)),
            ("shed_queue_full", Json::Num(self.shed_queue_full as f64)),
            ("shed_deadline", Json::Num(self.shed_deadline as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("simulated_mips", Json::Num(self.simulated_mips())),
            ("p50_ms", Json::Num(self.latency_ms(50.0))),
            ("p99_ms", Json::Num(self.latency_ms(99.0))),
            ("p99_9_ms", Json::Num(self.latency_ms(99.9))),
            ("batches", Json::Num(self.batches as f64)),
            ("batching_efficiency", Json::Num(self.batching_efficiency())),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("mean_queue_depth", Json::Num(self.mean_queue_depth)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("total_cycles", Json::Num(self.total_cycles as f64)),
            ("total_instret", Json::Num(self.total_instret as f64)),
            ("per_model_served", Json::num_arr(&per_model)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{model_zoo, prepare};
    use crate::pipeline::{CompileOptions, CompileSession};
    use crate::runtime::simrun;

    fn tiny_compiled() -> crate::pipeline::CompiledModel {
        let g = prepare(model_zoo::mlp(&[8, 4], 1)).unwrap();
        let mut s = CompileSession::new(CompileOptions::default());
        s.compile(&g).unwrap()
    }

    #[test]
    fn round_trip_serves_and_reports() {
        let img = Arc::new(ModelImage::from_compiled(&tiny_compiled()).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 2, max_batch: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.model_count(), 1);
        let mut tickets = Vec::new();
        for seed in 0..6u64 {
            tickets.push(server.submit(0, img.synth_request(0, seed)).unwrap());
        }
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.model, 0);
            assert_eq!(out.outputs.len(), 1);
            assert!(out.stats.instret > 0);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 6);
        assert_eq!(report.submitted, 6);
        assert_eq!(report.per_model_served, vec![6]);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert_eq!(report.batched_requests, 6);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.batching_efficiency() >= 1.0);
    }

    #[test]
    fn unknown_model_index_is_an_error() {
        let img = Arc::new(ModelImage::from_compiled(&tiny_compiled()).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert!(server.submit(1, img.synth_request(0, 0)).is_err());
        let report = server.shutdown();
        assert_eq!(report.submitted, 0);
    }

    #[test]
    fn served_output_matches_serial_run_model() {
        let c = tiny_compiled();
        let img = Arc::new(ModelImage::from_compiled(&c).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        let req = img.synth_request(0, 9);
        let out = server.submit(0, req.clone()).unwrap().wait().unwrap();
        server.shutdown();
        let fresh = simrun::run_model(&c.mach, &c.graph, c.abi(), &c.asm, &req.inputs).unwrap();
        assert_eq!(out.stats, fresh.stats);
        let a: Vec<u32> = out.outputs[0].data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = fresh.outputs[0].data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }
}
