//! Batched concurrent inference server over the fast simulator — the
//! production serving runtime.
//!
//! [`Server::start`] loads a fleet of [`ModelImage`]s and spawns a pool of
//! worker threads; every worker owns one long-lived [`LoadedModel`] per
//! model (predecode + weight staging happen once per worker × model; the
//! per-request cost is the `reset_keep_wmem` path: zero the live DMEM
//! extent, re-stage inputs, run). Requests flow through bounded per-model
//! queues:
//!
//! - **Batching:** a worker drains up to `max_batch` *compatible* requests
//!   (same model, same dims — dynamic-shape images batch per
//!   specialization) in one dequeue, amortizing lock traffic and keeping
//!   the machine's working set hot across the batch.
//! - **Backpressure:** [`Server::submit`] sheds with an error once a
//!   model's queue holds `queue_depth` requests (open-loop callers);
//!   [`Server::submit_blocking`] waits for space instead (closed-loop
//!   saturation drivers). With a `deadline`, requests that queued longer
//!   than the budget are shed *at dequeue* with an error — the server
//!   returns a late error, never a wrong answer.
//! - **Determinism:** workers add no numerical or timing state of their
//!   own; every served output and its [`RunStats`] are bit-identical to a
//!   serial [`LoadedModel::infer`] of the same request
//!   (`rust/tests/serving.rs` proves it under concurrency).
//!
//! # Fault tolerance
//!
//! The server assumes machines fail: traps, injected hardware faults, and
//! panicking kernels are part of the operating envelope, not exceptional
//! aborts. The discipline, end to end:
//!
//! - **Isolation.** Every request runs under `catch_unwind`; a panicking
//!   kernel fails one ticket with [`Error::Panic`], not the fleet. A panic
//!   that escapes a worker loop is caught by its supervisor, which rebuilds
//!   the worker's machines from the immutable images and respawns the loop;
//!   requests that were in flight resolve with a typed error (never a hang).
//! - **Recovery + retry.** Machine-scoped failures ([`Error::is_machine_scoped`]:
//!   traps and panics) discard the suspect machine via [`LoadedModel::rebuild`]
//!   and retry the request with bounded exponential backoff, as long as
//!   attempts and the request deadline allow. Request-scoped failures (bad
//!   shape, shed) are returned immediately — retrying cannot help.
//! - **Circuit breaking.** `breaker_threshold` consecutive machine-scoped
//!   request failures quarantine the model: submits shed with a
//!   "quarantined" error until `breaker_cooldown` elapses, then one
//!   half-open probe is admitted; its outcome closes or reopens the circuit.
//! - **Never a wrong answer.** A fault can cost a retry, a rebuild, or the
//!   request — it can never change served bits: every completed response is
//!   bit-identical (outputs *and* [`RunStats`]) to a serial fresh-machine
//!   run of the same request. `rust/tests/fault_tolerance.rs` and
//!   `benches/bench_fault_injection.rs` prove it under injected chaos.
//!
//! [`Server::shutdown`] closes the queues, drains what's enqueued, joins
//! the pool (harvesting worker panics instead of propagating them), fails
//! anything still queued with a typed error, and returns a [`ServerReport`]:
//! throughput (req/s and simulated MIPS), latency percentiles, batching
//! efficiency, queue-depth/shed accounting, and the fault-tolerance
//! counters (retries, rebuilds, panics, quarantine transitions) — what
//! `benches/bench_serving.rs` and `benches/bench_fault_injection.rs` emit
//! as JSON artifacts.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ir::tensor::Tensor;
use crate::runtime::engine::{InferenceRequest, LoadedModel, ModelImage};
use crate::sim::fault::FaultPlan;
use crate::sim::machine::RunStats;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::lock_recover;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Chaos-mode knobs: seeded fault/panic/crash injection rates the load
/// generator and the fault-tolerance suite drive the server with.
#[derive(Debug, Clone)]
pub struct ChaosOptions {
    /// Probability that a request attempt runs with a detected injected
    /// machine fault armed ([`FaultPlan::chaos`]).
    pub fault_rate: f64,
    /// Probability that a request attempt panics inside the worker.
    pub panic_rate: f64,
    /// Probability per dequeued batch that the whole worker thread crashes
    /// (exercises supervisor respawn + in-flight ticket resolution).
    pub crash_rate: f64,
    /// Seed for the per-worker chaos PRNG (deterministic chaos).
    pub seed: u64,
}

impl Default for ChaosOptions {
    fn default() -> ChaosOptions {
        ChaosOptions { fault_rate: 0.0, panic_rate: 0.0, crash_rate: 0.0, seed: 42 }
    }
}

/// Server tuning knobs (`xgenc serve` flags map 1:1 onto these).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Worker threads (0 = one per available core).
    pub workers: usize,
    /// Max compatible requests drained per dequeue (min 1).
    pub max_batch: usize,
    /// Per-model queue bound before `submit` sheds (min 1).
    pub queue_depth: usize,
    /// Shed requests that queued longer than this before dispatch.
    pub deadline: Option<Duration>,
    /// Max retry attempts after a machine-scoped failure (0 = fail fast).
    pub retries: u32,
    /// Initial retry backoff; doubles per attempt, bounded by `deadline`.
    pub retry_backoff: Duration,
    /// Consecutive machine-scoped request failures before a model is
    /// quarantined (min 1).
    pub breaker_threshold: u32,
    /// Quarantine duration before a half-open probe is admitted.
    pub breaker_cooldown: Duration,
    /// Fault/panic/crash injection (None = production, no chaos).
    pub chaos: Option<ChaosOptions>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            workers: 0,
            max_batch: 8,
            queue_depth: 256,
            deadline: None,
            retries: 2,
            retry_backoff: Duration::from_micros(200),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_millis(50),
            chaos: None,
        }
    }
}

/// One served request: which model ran, its outputs and per-run machine
/// stats, and the enqueue → completion latency.
#[derive(Debug)]
pub struct ServedOutput {
    pub model: usize,
    pub outputs: Vec<Tensor>,
    pub stats: RunStats,
    pub latency: Duration,
}

/// One-shot response slot a worker fills and a [`Ticket`] waits on.
struct Slot {
    result: Mutex<Option<Result<ServedOutput>>>,
    done: Condvar,
}

/// First write wins: a slot is filled exactly once (the explicit serve/shed
/// path, or the [`Pending`] drop glue when a worker crashed mid-flight).
fn fill(slot: &Slot, out: Result<ServedOutput>) {
    let mut r = lock_recover(&slot.result);
    if r.is_none() {
        *r = Some(out);
        slot.done.notify_all();
    }
}

/// Handle to one submitted request; [`Ticket::wait`] blocks until a worker
/// serves or sheds it. Never hangs: every accepted request's slot is filled
/// by the serve path, the crash drop glue, or the shutdown drain.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    pub fn wait(self) -> Result<ServedOutput> {
        let mut r = lock_recover(&self.slot.result);
        loop {
            if let Some(out) = r.take() {
                return out;
            }
            r = self
                .slot
                .done
                .wait(r)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}

struct Pending {
    model: usize,
    req: InferenceRequest,
    enqueued: Instant,
    slot: Arc<Slot>,
}

impl Drop for Pending {
    /// Crash glue: if this request is dropped with its slot still empty
    /// (a worker panicked while it was in flight, or a queue was dropped
    /// wholesale), resolve the ticket with a typed machine-scoped error so
    /// [`Ticket::wait`] can never hang.
    fn drop(&mut self) {
        fill(
            &self.slot,
            Err(Error::Panic("worker crashed with the request in flight".into())),
        );
    }
}

/// Per-model circuit breaker state (driven under the server state lock).
enum BreakerState {
    Closed,
    Open { since: Instant },
    HalfOpen,
}

struct Breaker {
    consecutive: u32,
    state: BreakerState,
}

/// Everything behind the server mutex: the per-model queues plus the
/// submit-side counters and circuit breakers maintained under the same lock.
struct State {
    queues: Vec<VecDeque<Pending>>,
    breakers: Vec<Breaker>,
    open: bool,
    submitted: u64,
    shed_queue_full: u64,
    shed_quarantine: u64,
    quarantine_opened: u64,
    quarantine_probes: u64,
    depth_samples: u64,
    depth_sum: u64,
    depth_max: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Signaled on enqueue and shutdown (workers wait here).
    work: Condvar,
    /// Signaled on dequeue (blocking submitters wait here).
    space: Condvar,
    opts: ServerOptions,
}

/// Per-worker accounting, merged at shutdown.
#[derive(Default)]
struct WorkerStats {
    served: u64,
    shed_deadline: u64,
    batches: u64,
    batched_requests: u64,
    max_batch_seen: usize,
    latencies_ms: Vec<f64>,
    cycles: u64,
    instret: u64,
    per_model_served: Vec<u64>,
    retries: u64,
    rebuilds: u64,
    machine_failures: u64,
    panics: u64,
    worker_respawns: u64,
}

/// The running server. Always finish with [`Server::shutdown`]; dropping
/// the handle without it would leave the worker threads parked forever.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<WorkerStats>>,
    started: Instant,
}

impl Server {
    /// Load the fleet and spawn the worker pool. Every worker stages every
    /// model's weights into its own machines up front — startup cost paid
    /// once, and load errors surface here rather than inside a thread.
    pub fn start(images: &[Arc<ModelImage>], opts: ServerOptions) -> Result<Server> {
        if images.is_empty() {
            return Err(Error::Runtime("server needs at least one model".into()));
        }
        let opts = ServerOptions {
            workers: crate::util::resolve_workers(opts.workers),
            max_batch: opts.max_batch.max(1),
            queue_depth: opts.queue_depth.max(1),
            breaker_threshold: opts.breaker_threshold.max(1),
            ..opts
        };
        let mut fleets: Vec<Vec<LoadedModel>> = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let mut fleet = Vec::with_capacity(images.len());
            for img in images {
                fleet.push(LoadedModel::from_image(img.clone())?);
            }
            fleets.push(fleet);
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queues: images.iter().map(|_| VecDeque::new()).collect(),
                breakers: images
                    .iter()
                    .map(|_| Breaker { consecutive: 0, state: BreakerState::Closed })
                    .collect(),
                open: true,
                submitted: 0,
                shed_queue_full: 0,
                shed_quarantine: 0,
                quarantine_opened: 0,
                quarantine_probes: 0,
                depth_samples: 0,
                depth_sum: 0,
                depth_max: 0,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            opts,
        });
        let handles = fleets
            .into_iter()
            .enumerate()
            .map(|(w, fleet)| {
                let shared = Arc::clone(&shared);
                let images: Vec<Arc<ModelImage>> = images.to_vec();
                std::thread::spawn(move || supervise(&shared, &images, fleet, w))
            })
            .collect();
        Ok(Server { shared, handles, started: Instant::now() })
    }

    pub fn model_count(&self) -> usize {
        lock_recover(&self.shared.state).queues.len()
    }

    /// Enqueue a request; sheds with an error when the model's queue is
    /// full (graceful backpressure for open-loop arrivals) or the model is
    /// quarantined by its circuit breaker.
    pub fn submit(&self, model: usize, req: InferenceRequest) -> Result<Ticket> {
        self.enqueue(model, req, false)
    }

    /// Enqueue a request, waiting for queue space instead of shedding —
    /// the closed-loop saturation driver.
    pub fn submit_blocking(&self, model: usize, req: InferenceRequest) -> Result<Ticket> {
        self.enqueue(model, req, true)
    }

    fn enqueue(&self, model: usize, req: InferenceRequest, block: bool) -> Result<Ticket> {
        let shared = &self.shared;
        let mut st = lock_recover(&shared.state);
        if model >= st.queues.len() {
            return Err(Error::Runtime(format!(
                "unknown model index {model} (fleet has {})",
                st.queues.len()
            )));
        }
        if block {
            while st.open && st.queues[model].len() >= shared.opts.queue_depth {
                st = shared.space.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        if !st.open {
            return Err(Error::Runtime("server is shut down".into()));
        }
        // Circuit breaker: quarantined models shed at submit; after the
        // cooldown one half-open probe is admitted to test recovery.
        match st.breakers[model].state {
            BreakerState::Open { since } => {
                if since.elapsed() >= shared.opts.breaker_cooldown {
                    st.breakers[model].state = BreakerState::HalfOpen;
                    st.quarantine_probes += 1;
                } else {
                    st.shed_quarantine += 1;
                    return Err(Error::Runtime(format!(
                        "shed: model {model} quarantined (circuit open after {} \
                         consecutive machine failures)",
                        st.breakers[model].consecutive
                    )));
                }
            }
            BreakerState::HalfOpen => {
                // A probe is already in flight; keep shedding until it
                // resolves the breaker one way or the other.
                st.shed_quarantine += 1;
                return Err(Error::Runtime(format!(
                    "shed: model {model} quarantined (half-open probe in flight)"
                )));
            }
            BreakerState::Closed => {}
        }
        if st.queues[model].len() >= shared.opts.queue_depth {
            st.shed_queue_full += 1;
            return Err(Error::Runtime(format!(
                "shed: queue full for model {model} ({} pending)",
                st.queues[model].len()
            )));
        }
        let slot = Arc::new(Slot { result: Mutex::new(None), done: Condvar::new() });
        st.queues[model].push_back(Pending {
            model,
            req,
            enqueued: Instant::now(),
            slot: Arc::clone(&slot),
        });
        st.submitted += 1;
        let depth = st.queues[model].len();
        st.depth_samples += 1;
        st.depth_sum += depth as u64;
        st.depth_max = st.depth_max.max(depth);
        drop(st);
        shared.work.notify_one();
        Ok(Ticket { slot })
    }

    /// Close the queues, let the workers drain what is already enqueued,
    /// join the pool — harvesting panicked workers instead of propagating —
    /// fail anything still queued with a typed error, and return the merged
    /// report. After this returns, every ticket ever issued has resolved.
    pub fn shutdown(self) -> ServerReport {
        {
            let mut st = lock_recover(&self.shared.state);
            st.open = false;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        let workers = self.handles.len();
        let mut merged = WorkerStats::default();
        let mut crashed_workers = 0u64;
        for h in self.handles {
            match h.join() {
                Ok(w) => {
                    merged.served += w.served;
                    merged.shed_deadline += w.shed_deadline;
                    merged.batches += w.batches;
                    merged.batched_requests += w.batched_requests;
                    merged.max_batch_seen = merged.max_batch_seen.max(w.max_batch_seen);
                    merged.latencies_ms.extend(w.latencies_ms);
                    merged.cycles += w.cycles;
                    merged.instret += w.instret;
                    merged.retries += w.retries;
                    merged.rebuilds += w.rebuilds;
                    merged.machine_failures += w.machine_failures;
                    merged.panics += w.panics;
                    merged.worker_respawns += w.worker_respawns;
                    if merged.per_model_served.len() < w.per_model_served.len() {
                        merged.per_model_served.resize(w.per_model_served.len(), 0);
                    }
                    for (m, n) in w.per_model_served.iter().enumerate() {
                        merged.per_model_served[m] += n;
                    }
                }
                // A supervisor itself died; its stats are lost but shutdown
                // must not: the queue drain below keeps every ticket resolved.
                Err(_) => crashed_workers += 1,
            }
        }
        merged.panics += crashed_workers;
        let wall_seconds = self.started.elapsed().as_secs_f64();
        let mut st = lock_recover(&self.shared.state);
        // Workers normally drain the queues before exiting; if any died for
        // good, fail the leftovers with a typed error so no Ticket hangs.
        let mut failed_at_shutdown = 0u64;
        for q in st.queues.iter_mut() {
            while let Some(p) = q.pop_front() {
                failed_at_shutdown += 1;
                fill(
                    &p.slot,
                    Err(Error::Runtime(
                        "server shut down before serving this request".into(),
                    )),
                );
            }
        }
        ServerReport {
            workers,
            wall_seconds,
            submitted: st.submitted,
            served: merged.served,
            shed_queue_full: st.shed_queue_full,
            shed_deadline: merged.shed_deadline,
            shed_quarantine: st.shed_quarantine,
            failed_at_shutdown,
            batches: merged.batches,
            batched_requests: merged.batched_requests,
            max_batch: merged.max_batch_seen,
            total_cycles: merged.cycles,
            total_instret: merged.instret,
            per_model_served: merged.per_model_served,
            latencies_ms: merged.latencies_ms,
            mean_queue_depth: if st.depth_samples == 0 {
                0.0
            } else {
                st.depth_sum as f64 / st.depth_samples as f64
            },
            max_queue_depth: st.depth_max,
            retries: merged.retries,
            rebuilds: merged.rebuilds,
            machine_failures: merged.machine_failures,
            panics: merged.panics,
            worker_respawns: merged.worker_respawns,
            quarantine_opened: st.quarantine_opened,
            quarantine_probes: st.quarantine_probes,
        }
    }
}

/// Reset a model's breaker after a served request.
fn breaker_success(shared: &Shared, model: usize) {
    let mut st = lock_recover(&shared.state);
    let b = &mut st.breakers[model];
    b.consecutive = 0;
    b.state = BreakerState::Closed;
}

/// Record a machine-scoped request failure; trips the breaker at the
/// configured threshold (immediately, for a failed half-open probe).
fn breaker_failure(shared: &Shared, model: usize) {
    let mut st = lock_recover(&shared.state);
    let tripped = {
        let b = &mut st.breakers[model];
        b.consecutive += 1;
        let should_open = matches!(b.state, BreakerState::HalfOpen)
            || b.consecutive >= shared.opts.breaker_threshold;
        if should_open && !matches!(b.state, BreakerState::Open { .. }) {
            b.state = BreakerState::Open { since: Instant::now() };
            true
        } else {
            false
        }
    };
    if tripped {
        st.quarantine_opened += 1;
    }
}

/// Supervisor for one worker slot: run the worker loop, and when it
/// panics (chaos crash injection or a real bug escaping the per-request
/// isolation), rebuild the whole fleet from the immutable images and
/// respawn the loop. In-flight requests of the crashed loop resolve via
/// the [`Pending`] drop glue. Returns the accumulated stats at shutdown.
fn supervise(
    shared: &Shared,
    images: &[Arc<ModelImage>],
    mut fleet: Vec<LoadedModel>,
    wid: usize,
) -> WorkerStats {
    let mut stats =
        WorkerStats { per_model_served: vec![0; images.len()], ..Default::default() };
    loop {
        let exited =
            catch_unwind(AssertUnwindSafe(|| worker_loop(shared, &mut fleet, wid, &mut stats)));
        if exited.is_ok() {
            return stats; // clean shutdown
        }
        stats.panics += 1;
        stats.worker_respawns += 1;
        let rebuilt: Result<Vec<LoadedModel>> = images
            .iter()
            .map(|img| LoadedModel::from_image(Arc::clone(img)))
            .collect();
        match rebuilt {
            Ok(f) => fleet = f,
            // Cannot rebuild a servable fleet: give up this slot. Other
            // workers keep serving; the shutdown drain resolves leftovers.
            Err(_) => return stats,
        }
    }
}

fn worker_loop(
    shared: &Shared,
    fleet: &mut [LoadedModel],
    wid: usize,
    stats: &mut WorkerStats,
) {
    let n_models = fleet.len();
    // Deterministic per-worker chaos stream (respawns restart it).
    let mut chaos: Option<(ChaosOptions, Rng)> = shared.opts.chaos.clone().map(|c| {
        let rng = Rng::new(c.seed ^ (wid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        (c, rng)
    });
    // Stagger starting queues across workers so a mixed fleet doesn't
    // funnel every worker onto model 0.
    let mut cursor = wid % n_models;
    loop {
        let mut batch: Vec<Pending> = Vec::new();
        {
            let mut st = lock_recover(&shared.state);
            loop {
                let found = (0..n_models)
                    .map(|k| (cursor + k) % n_models)
                    .find(|&qi| !st.queues[qi].is_empty());
                if let Some(qi) = found {
                    cursor = (qi + 1) % n_models;
                    let q = &mut st.queues[qi];
                    let first = q.pop_front().unwrap();
                    let dims = first.req.dims.clone();
                    batch.push(first);
                    while batch.len() < shared.opts.max_batch
                        && q.front().is_some_and(|p| p.req.dims == dims)
                    {
                        batch.push(q.pop_front().unwrap());
                    }
                    break;
                }
                if !st.open {
                    return;
                }
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        shared.space.notify_all();
        stats.batches += 1;
        stats.batched_requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());
        // Chaos: whole-worker crash with the batch in flight — the batch's
        // Pending drop glue resolves its tickets, the supervisor respawns.
        if let Some((c, rng)) = chaos.as_mut() {
            if c.crash_rate > 0.0 && rng.chance(c.crash_rate) {
                panic!("chaos: injected worker crash");
            }
        }
        for p in batch {
            if let Some(deadline) = shared.opts.deadline {
                let waited = p.enqueued.elapsed();
                if waited > deadline {
                    stats.shed_deadline += 1;
                    fill(
                        &p.slot,
                        Err(Error::Runtime(format!(
                            "shed: deadline exceeded ({:.1} ms queued > {:.1} ms budget)",
                            waited.as_secs_f64() * 1e3,
                            deadline.as_secs_f64() * 1e3
                        ))),
                    );
                    continue;
                }
            }
            serve_one(shared, fleet, &p, &mut chaos, stats);
        }
    }
}

/// Serve one request with per-request panic isolation, machine rebuild on
/// machine-scoped failure, bounded exponential-backoff retry under the
/// deadline, and circuit-breaker accounting.
fn serve_one(
    shared: &Shared,
    fleet: &mut [LoadedModel],
    p: &Pending,
    chaos: &mut Option<(ChaosOptions, Rng)>,
    stats: &mut WorkerStats,
) {
    let mut backoff = shared.opts.retry_backoff;
    let mut attempt = 0u32;
    loop {
        // Chaos: arm an injected machine fault and/or a kernel panic for
        // this attempt. Injected faults are *detected* — they trap, they
        // never silently corrupt a served answer.
        let mut chaos_panic = false;
        if let Some((c, rng)) = chaos.as_mut() {
            if c.fault_rate > 0.0 && rng.chance(c.fault_rate) {
                fleet[p.model].arm_faults(FaultPlan::chaos(rng.next_u64()));
            }
            chaos_panic = c.panic_rate > 0.0 && rng.chance(c.panic_rate);
        }
        let lm = &mut fleet[p.model];
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if chaos_panic {
                panic!("chaos: injected kernel panic");
            }
            lm.infer(&p.req)
        }));
        let err = match outcome {
            Ok(Ok(resp)) => {
                breaker_success(shared, p.model);
                stats.served += 1;
                stats.per_model_served[p.model] += 1;
                stats.cycles += resp.stats.cycles;
                stats.instret += resp.stats.instret;
                let latency = p.enqueued.elapsed();
                stats.latencies_ms.push(latency.as_secs_f64() * 1e3);
                fill(
                    &p.slot,
                    Ok(ServedOutput {
                        model: p.model,
                        outputs: resp.outputs,
                        stats: resp.stats,
                        latency,
                    }),
                );
                return;
            }
            // Request-scoped: the request itself is bad (shape validation);
            // the machine is fine and retrying cannot help.
            Ok(Err(e)) if !e.is_machine_scoped() => {
                fill(&p.slot, Err(e));
                return;
            }
            Ok(Err(e)) => e,
            Err(panic) => {
                stats.panics += 1;
                Error::Panic(panic_message(&panic))
            }
        };
        // Machine-scoped failure: the machine is suspect (partial writes,
        // flipped bits, caught panic mid-run) — rebuild it from the image.
        stats.machine_failures += 1;
        if fleet[p.model].rebuild().is_ok() {
            stats.rebuilds += 1;
        }
        attempt += 1;
        let deadline_allows = match shared.opts.deadline {
            None => true,
            Some(d) => p.enqueued.elapsed() + backoff <= d,
        };
        if attempt > shared.opts.retries || !deadline_allows {
            breaker_failure(shared, p.model);
            fill(&p.slot, Err(err));
            return;
        }
        stats.retries += 1;
        if !backoff.is_zero() {
            std::thread::sleep(backoff);
        }
        backoff = backoff.saturating_mul(2);
    }
}

/// Best-effort extraction of a panic payload message.
fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Merged serving metrics for one server lifetime.
pub struct ServerReport {
    pub workers: usize,
    pub wall_seconds: f64,
    /// Requests accepted into a queue (submit-side sheds are not counted).
    pub submitted: u64,
    pub served: u64,
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    /// Submits shed because the model's circuit breaker was open.
    pub shed_quarantine: u64,
    /// Accepted requests failed with a typed error by the shutdown drain.
    pub failed_at_shutdown: u64,
    /// Dequeue operations and the requests they carried — efficiency is
    /// `batched_requests / batches`.
    pub batches: u64,
    pub batched_requests: u64,
    /// Largest single batch observed.
    pub max_batch: usize,
    pub total_cycles: u64,
    pub total_instret: u64,
    pub per_model_served: Vec<u64>,
    /// Enqueue → completion latency of every served request, in ms.
    pub latencies_ms: Vec<f64>,
    /// Queue depth sampled at every accepted submit.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Retry attempts after machine-scoped failures.
    pub retries: u64,
    /// Machine rebuilds from the immutable image.
    pub rebuilds: u64,
    /// Request attempts that ended in a machine-scoped failure.
    pub machine_failures: u64,
    /// Panics caught (per-request isolation + worker crashes).
    pub panics: u64,
    /// Worker loops respawned by their supervisor after a crash.
    pub worker_respawns: u64,
    /// Circuit-breaker transitions into quarantine.
    pub quarantine_opened: u64,
    /// Half-open probes admitted after a quarantine cooldown.
    pub quarantine_probes: u64,
}

impl ServerReport {
    /// Served requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.wall_seconds.max(1e-9)
    }

    /// Simulated instructions retired per wall-clock second, in millions.
    pub fn simulated_mips(&self) -> f64 {
        self.total_instret as f64 / self.wall_seconds.max(1e-9) / 1e6
    }

    /// Mean requests per dequeue (1.0 = no batching benefit).
    pub fn batching_efficiency(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Latency percentile in ms (`p` in `[0, 100]`); 0 when nothing served.
    pub fn latency_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            percentile(&self.latencies_ms, p)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} workers: {} served in {:.2}s ({:.0} req/s, {:.1} simulated MIPS) | \
             p50 {:.3} ms p99 {:.3} ms p99.9 {:.3} ms | batch {:.2} avg / {} max | \
             queue {:.1} avg / {} max | shed {} full + {} deadline + {} quarantine | \
             faults: {} machine failures, {} retries, {} rebuilds, {} panics, \
             {} respawns, {} quarantines opened",
            self.workers,
            self.served,
            self.wall_seconds,
            self.throughput_rps(),
            self.simulated_mips(),
            self.latency_ms(50.0),
            self.latency_ms(99.0),
            self.latency_ms(99.9),
            self.batching_efficiency(),
            self.max_batch,
            self.mean_queue_depth,
            self.max_queue_depth,
            self.shed_queue_full,
            self.shed_deadline,
            self.shed_quarantine,
            self.machine_failures,
            self.retries,
            self.rebuilds,
            self.panics,
            self.worker_respawns,
            self.quarantine_opened,
        )
    }

    pub fn to_json(&self) -> Json {
        let per_model: Vec<f64> = self.per_model_served.iter().map(|n| *n as f64).collect();
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("submitted", Json::Num(self.submitted as f64)),
            ("served", Json::Num(self.served as f64)),
            ("shed_queue_full", Json::Num(self.shed_queue_full as f64)),
            ("shed_deadline", Json::Num(self.shed_deadline as f64)),
            ("shed_quarantine", Json::Num(self.shed_quarantine as f64)),
            ("failed_at_shutdown", Json::Num(self.failed_at_shutdown as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("simulated_mips", Json::Num(self.simulated_mips())),
            ("p50_ms", Json::Num(self.latency_ms(50.0))),
            ("p99_ms", Json::Num(self.latency_ms(99.0))),
            ("p99_9_ms", Json::Num(self.latency_ms(99.9))),
            ("batches", Json::Num(self.batches as f64)),
            ("batching_efficiency", Json::Num(self.batching_efficiency())),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("mean_queue_depth", Json::Num(self.mean_queue_depth)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("total_cycles", Json::Num(self.total_cycles as f64)),
            ("total_instret", Json::Num(self.total_instret as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("rebuilds", Json::Num(self.rebuilds as f64)),
            ("machine_failures", Json::Num(self.machine_failures as f64)),
            ("panics", Json::Num(self.panics as f64)),
            ("worker_respawns", Json::Num(self.worker_respawns as f64)),
            ("quarantine_opened", Json::Num(self.quarantine_opened as f64)),
            ("quarantine_probes", Json::Num(self.quarantine_probes as f64)),
            ("per_model_served", Json::num_arr(&per_model)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{model_zoo, prepare};
    use crate::pipeline::{CompileOptions, CompileSession};
    use crate::runtime::simrun;

    fn tiny_compiled() -> crate::pipeline::CompiledModel {
        let g = prepare(model_zoo::mlp(&[8, 4], 1)).unwrap();
        let mut s = CompileSession::new(CompileOptions::default());
        s.compile(&g).unwrap()
    }

    #[test]
    fn round_trip_serves_and_reports() {
        let img = Arc::new(ModelImage::from_compiled(&tiny_compiled()).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 2, max_batch: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.model_count(), 1);
        let mut tickets = Vec::new();
        for seed in 0..6u64 {
            tickets.push(server.submit(0, img.synth_request(0, seed)).unwrap());
        }
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.model, 0);
            assert_eq!(out.outputs.len(), 1);
            assert!(out.stats.instret > 0);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 6);
        assert_eq!(report.submitted, 6);
        assert_eq!(report.per_model_served, vec![6]);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert_eq!(report.batched_requests, 6);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.batching_efficiency() >= 1.0);
        // Fault-free serving touches none of the fault-tolerance machinery.
        assert_eq!(report.retries, 0);
        assert_eq!(report.rebuilds, 0);
        assert_eq!(report.panics, 0);
        assert_eq!(report.quarantine_opened, 0);
    }

    #[test]
    fn unknown_model_index_is_an_error() {
        let img = Arc::new(ModelImage::from_compiled(&tiny_compiled()).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert!(server.submit(1, img.synth_request(0, 0)).is_err());
        let report = server.shutdown();
        assert_eq!(report.submitted, 0);
    }

    #[test]
    fn served_output_matches_serial_run_model() {
        let c = tiny_compiled();
        let img = Arc::new(ModelImage::from_compiled(&c).unwrap());
        let server = Server::start(
            &[Arc::clone(&img)],
            ServerOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        let req = img.synth_request(0, 9);
        let out = server.submit(0, req.clone()).unwrap().wait().unwrap();
        server.shutdown();
        let fresh = simrun::run_model(&c.mach, &c.graph, c.abi(), &c.asm, &req.inputs).unwrap();
        assert_eq!(out.stats, fresh.stats);
        let a: Vec<u32> = out.outputs[0].data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = fresh.outputs[0].data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }
}
