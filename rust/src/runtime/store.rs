//! On-disk JSON artifact store: small typed documents the compiler persists
//! between runs (tuning caches, bench reports). Writes are atomic
//! (temp-file + rename) so a crashed compile never leaves a truncated
//! artifact for the next run to choke on.

use std::io::Write;
use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Atomically and durably write a JSON document (pretty-printed, trailing
/// newline). The temp name is unique per process + call, so concurrent
/// writers of the same artifact cannot interleave inside one temp file:
/// last rename wins with intact content either way. The temp file is
/// fsynced before the rename — a crash right after `save_json` returns
/// cannot surface the *old* name with the *new* (unflushed) content — and
/// removed if the rename itself fails, so aborted writes don't litter the
/// artifact directory.
pub fn save_json(path: &Path, doc: &Json) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(
        ".tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let tmp = std::path::PathBuf::from(tmp);
    let mut text = doc.to_string_pretty();
    text.push('\n');
    if let Err(e) = write_synced(&tmp, &text).and_then(|_| std::fs::rename(&tmp, path)) {
        let _ = std::fs::remove_file(&tmp);
        return Err(Error::Io(e));
    }
    Ok(())
}

/// Create + write + fsync the temp file (the pre-rename half of
/// [`save_json`]).
fn write_synced(tmp: &Path, text: &str) -> std::io::Result<()> {
    let mut f = std::fs::File::create(tmp)?;
    f.write_all(text.as_bytes())?;
    f.sync_all()
}

/// Load and parse a JSON document.
pub fn load_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Runtime(format!("{}: {e}", path.display())))?;
    Json::parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("xgenc_store_{}_{name}", std::process::id()))
    }

    #[test]
    fn json_round_trips_through_disk() {
        let path = tmp_path("rt.json");
        let doc = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("items", Json::num_arr(&[1.0, 2.5, -3.0])),
        ]);
        save_json(&path, &doc).unwrap();
        assert_eq!(load_json(&path).unwrap(), doc);
        // Overwrite is atomic and idempotent.
        save_json(&path, &doc).unwrap();
        assert_eq!(load_json(&path).unwrap(), doc);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(load_json(&tmp_path("nonexistent.json")).is_err());
    }

    #[test]
    fn concurrent_writers_never_leave_a_torn_file() {
        let path = tmp_path("concurrent.json");
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let path = path.clone();
                s.spawn(move || {
                    for i in 0..25u64 {
                        let doc = Json::obj(vec![
                            ("writer", Json::Num(t as f64)),
                            ("iter", Json::Num(i as f64)),
                            ("payload", Json::num_arr(&[t as f64; 64])),
                        ]);
                        save_json(&path, &doc).unwrap();
                        // Whatever is on disk at any instant parses whole.
                        load_json(&path).unwrap();
                    }
                });
            }
        });
        // No temp litter left behind.
        let dir = path.parent().unwrap();
        let stem = path.file_name().unwrap().to_string_lossy().to_string();
        let leftovers = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let n = e.file_name().to_string_lossy().to_string();
                n.starts_with(&stem) && n.contains(".tmp.")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_file(&path);
    }
}
