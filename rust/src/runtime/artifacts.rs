//! Typed wrappers over the four AOT artifacts. Shapes here mirror
//! `python/compile/model.py::aot_entries()` — the frozen interchange
//! contract (checked against `artifacts/manifest.json` at load).
//!
//! Built without the `pjrt` feature (the default — the `xla` crate needs a
//! vendored XLA toolchain), the same API compiles to a stub that reports
//! artifacts unavailable; callers skip gracefully onto the pure-rust
//! backends, exactly like a machine where `make artifacts` never ran.

use crate::cost::features::NUM_FEATURES;
use crate::cost::learned::{LinearBackend, BATCH};
use crate::util::error::{Error, Result};
#[cfg(feature = "pjrt")]
use crate::util::json::Json;

/// Fixed AOT shapes (must match python/compile/model.py).
pub const F: usize = NUM_FEATURES; // 16
pub const B: usize = BATCH; // 64
pub const HIST: usize = 2048;
pub const CAND: usize = 100;
pub const QAT_ROWS: usize = 32;
pub const QAT_LANES: usize = 128;

/// Locate the artifacts directory: $XGENC_ARTIFACTS or ./artifacts.
fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("XGENC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Loaded + compiled artifacts.
#[cfg(feature = "pjrt")]
pub struct Artifacts {
    client: xla::PjRtClient,
    cost_predict: xla::PjRtLoadedExecutable,
    cost_train: xla::PjRtLoadedExecutable,
    kl_calib: xla::PjRtLoadedExecutable,
    qat_step: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
fn load_exe(
    client: &xla::PjRtClient,
    dir: &std::path::Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
    )
    .map_err(|e| Error::Runtime(format!("{name}: parse failed: {e:?}")))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| Error::Runtime(format!("{name}: compile failed: {e:?}")))
}

#[cfg(feature = "pjrt")]
fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::Runtime(format!("literal reshape: {e:?}")))
}

#[cfg(feature = "pjrt")]
impl Artifacts {
    /// Locate the artifacts directory: $XGENC_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> std::path::PathBuf {
        artifacts_dir()
    }

    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }

    /// Load and compile all four artifacts on the PJRT CPU client.
    pub fn load() -> Result<Artifacts> {
        Self::load_from(&Self::default_dir())
    }

    pub fn load_from(dir: &std::path::Path) -> Result<Artifacts> {
        // Manifest check: catches stale artifacts after kernel edits.
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Runtime(format!("artifacts manifest missing ({e}); run `make artifacts`")))?;
        let manifest = Json::parse(&manifest_text)?;
        for name in ["cost_predict", "cost_train", "kl_calib", "qat_step"] {
            if manifest.get(name).as_obj().is_none() {
                return Err(Error::Runtime(format!("manifest missing entry '{name}'")));
            }
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT client: {e:?}")))?;
        Ok(Artifacts {
            cost_predict: load_exe(&client, dir, "cost_predict")?,
            cost_train: load_exe(&client, dir, "cost_train")?,
            kl_calib: load_exe(&client, dir, "kl_calib")?,
            qat_step: load_exe(&client, dir, "qat_step")?,
            client,
        })
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute: {e:?}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch: {e:?}")))?;
        lit.to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple: {e:?}")))
    }

    /// Batched cost prediction: y[B] = X[B,F] · w[F] (paper eq. 1).
    pub fn cost_predict(&self, w: &[f32; F], x: &[[f32; F]; B]) -> Result<Vec<f32>> {
        let wl = lit_f32(w, &[F as i64])?;
        let flat: Vec<f32> = x.iter().flatten().copied().collect();
        let xl = lit_f32(&flat, &[B as i64, F as i64])?;
        let outs = Self::run(&self.cost_predict, &[wl, xl])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("{e:?}")))
    }

    /// One training step (paper eq. 2 + momentum): returns (w', v', loss).
    pub fn cost_train(
        &self,
        w: &[f32; F],
        v: &[f32; F],
        x: &[[f32; F]; B],
        y: &[f32; B],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let wl = lit_f32(w, &[F as i64])?;
        let vl = lit_f32(v, &[F as i64])?;
        let flat: Vec<f32> = x.iter().flatten().copied().collect();
        let xl = lit_f32(&flat, &[B as i64, F as i64])?;
        let yl = lit_f32(y, &[B as i64])?;
        let lrl = lit_f32(&[lr], &[1])?;
        let outs = Self::run(&self.cost_train, &[wl, vl, xl, yl, lrl])?;
        let w2 = outs[0].to_vec::<f32>().map_err(|e| Error::Runtime(format!("{e:?}")))?;
        let v2 = outs[1].to_vec::<f32>().map_err(|e| Error::Runtime(format!("{e:?}")))?;
        let loss = outs[2].to_vec::<f32>().map_err(|e| Error::Runtime(format!("{e:?}")))?[0];
        Ok((w2, v2, loss))
    }

    /// Full KL calibration sweep (paper eq. 5): returns (per-candidate KL,
    /// argmin index).
    pub fn kl_calibrate(&self, hist: &[f32]) -> Result<(Vec<f32>, usize)> {
        if hist.len() != HIST {
            return Err(Error::Runtime(format!("histogram must be {HIST} bins")));
        }
        let hl = lit_f32(hist, &[HIST as i64])?;
        let outs = Self::run(&self.kl_calib, &[hl])?;
        let kls = outs[0].to_vec::<f32>().map_err(|e| Error::Runtime(format!("{e:?}")))?;
        let best = outs[1].to_vec::<i32>().map_err(|e| Error::Runtime(format!("{e:?}")))?[0];
        Ok((kls, best as usize))
    }

    /// One QAT block step (paper eqs. 8-13): returns
    /// (x_fq, dx, scale', zp', v_scale', v_zp').
    #[allow(clippy::too_many_arguments)]
    pub fn qat_step(
        &self,
        x: &[f32],
        g: &[f32],
        scale: f32,
        zp: f32,
        v_scale: f32,
        v_zp: f32,
        lr: f32,
        qlo: f32,
        qhi: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, f32, f32, f32)> {
        let n = QAT_ROWS * QAT_LANES;
        if x.len() != n || g.len() != n {
            return Err(Error::Runtime(format!("QAT block must be {n} values")));
        }
        let dims = [QAT_ROWS as i64, QAT_LANES as i64];
        let outs = Self::run(
            &self.qat_step,
            &[
                lit_f32(x, &dims)?,
                lit_f32(g, &dims)?,
                lit_f32(&[scale], &[1])?,
                lit_f32(&[zp], &[1])?,
                lit_f32(&[v_scale], &[1])?,
                lit_f32(&[v_zp], &[1])?,
                lit_f32(&[lr], &[1])?,
                lit_f32(&[qlo], &[1])?,
                lit_f32(&[qhi], &[1])?,
            ],
        )?;
        let take = |i: usize| -> Result<Vec<f32>> {
            outs[i].to_vec::<f32>().map_err(|e| Error::Runtime(format!("{e:?}")))
        };
        Ok((
            take(0)?,
            take(1)?,
            take(2)?[0],
            take(3)?[0],
            take(4)?[0],
            take(5)?[0],
        ))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// PJRT-backed linear backend for the learned cost model — the production
/// configuration (the f64 rust fallback differs only by f32 rounding).
pub struct PjrtBackend {
    pub artifacts: std::sync::Arc<Artifacts>,
}

#[cfg(feature = "pjrt")]
impl LinearBackend for PjrtBackend {
    fn predict(&mut self, w: &[f64; F], x: &[[f64; F]]) -> Vec<f64> {
        let wf: [f32; F] = std::array::from_fn(|i| w[i] as f32);
        let mut out = Vec::with_capacity(x.len());
        for chunk in x.chunks(B) {
            let mut xb = [[0f32; F]; B];
            for (i, row) in chunk.iter().enumerate() {
                for j in 0..F {
                    xb[i][j] = row[j] as f32;
                }
            }
            let ys = self.artifacts.cost_predict(&wf, &xb).expect("pjrt predict");
            out.extend(ys[..chunk.len()].iter().map(|&v| v as f64));
        }
        out
    }

    fn train_step(
        &mut self,
        w: &[f64; F],
        v: &[f64; F],
        x: &[[f64; F]],
        y: &[f64],
        lr: f64,
    ) -> ([f64; F], [f64; F], f64) {
        let wf: [f32; F] = std::array::from_fn(|i| w[i] as f32);
        let vf: [f32; F] = std::array::from_fn(|i| v[i] as f32);
        let mut xb = [[0f32; F]; B];
        let mut yb = [0f32; B];
        for i in 0..B {
            let src = i % x.len();
            for j in 0..F {
                xb[i][j] = x[src][j] as f32;
            }
            yb[i] = y[src] as f32;
        }
        let (w2, v2, loss) = self
            .artifacts
            .cost_train(&wf, &vf, &xb, &yb, lr as f32)
            .expect("pjrt train");
        (
            std::array::from_fn(|i| w2[i] as f64),
            std::array::from_fn(|i| v2[i] as f64),
            loss as f64,
        )
    }
}

// ---------------------------------------------------------------------------
// Stub build (default): same surface, artifacts never available.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
fn unavailable() -> Error {
    Error::Runtime(
        "PJRT runtime not built (compile with `--features pjrt` and a vendored `xla` crate)"
            .into(),
    )
}

/// Stub artifacts handle: [`Artifacts::available`] is always `false`, so
/// parity tests and the learned-model production path skip onto the
/// pure-rust backends.
#[cfg(not(feature = "pjrt"))]
pub struct Artifacts {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Artifacts {
    pub fn default_dir() -> std::path::PathBuf {
        artifacts_dir()
    }

    pub fn available() -> bool {
        false
    }

    pub fn load() -> Result<Artifacts> {
        Err(unavailable())
    }

    pub fn load_from(_dir: &std::path::Path) -> Result<Artifacts> {
        Err(unavailable())
    }

    pub fn cost_predict(&self, _w: &[f32; F], _x: &[[f32; F]; B]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    pub fn cost_train(
        &self,
        _w: &[f32; F],
        _v: &[f32; F],
        _x: &[[f32; F]; B],
        _y: &[f32; B],
        _lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        Err(unavailable())
    }

    pub fn kl_calibrate(&self, _hist: &[f32]) -> Result<(Vec<f32>, usize)> {
        Err(unavailable())
    }

    #[allow(clippy::too_many_arguments)]
    pub fn qat_step(
        &self,
        _x: &[f32],
        _g: &[f32],
        _scale: f32,
        _zp: f32,
        _v_scale: f32,
        _v_zp: f32,
        _lr: f32,
        _qlo: f32,
        _qhi: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, f32, f32, f32)> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the 'pjrt' feature)".into()
    }
}

#[cfg(not(feature = "pjrt"))]
impl LinearBackend for PjrtBackend {
    fn predict(&mut self, _w: &[f64; F], _x: &[[f64; F]]) -> Vec<f64> {
        unreachable!("PJRT runtime not built; Artifacts::available() is false")
    }

    fn train_step(
        &mut self,
        _w: &[f64; F],
        _v: &[f64; F],
        _x: &[[f64; F]],
        _y: &[f64],
        _lr: f64,
    ) -> ([f64; F], [f64; F], f64) {
        unreachable!("PJRT runtime not built; Artifacts::available() is false")
    }
}
