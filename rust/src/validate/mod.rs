//! Validation-driven compilation (paper §3.6, contribution 3): ISA
//! compliance and memory-constraint checks run *inside* the pipeline, before
//! anything is emitted — validation failures are compile errors, never
//! runtime surprises on silicon.

use std::collections::BTreeSet;

use crate::backend::memplan::{MemPlan, ModelAbi, ALIGN};
use crate::backend::regalloc;
use crate::ir::Graph;
use crate::isa::encode::{self, format_of, Format};
use crate::isa::{decode, Instr, Op};
use crate::sim::{layout, MachineConfig};
use crate::util::error::{Error, Result};

/// A validation report: every check with its outcome.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub checks: Vec<(String, bool, String)>,
    pub instructions_checked: usize,
}

impl Report {
    fn check(&mut self, name: &str, ok: bool, detail: String) {
        self.checks.push((name.to_string(), ok, detail));
    }

    pub fn passed(&self) -> bool {
        self.checks.iter().all(|(_, ok, _)| *ok)
    }

    /// "100% ISA validation passed" line for reports (case study 1).
    pub fn summary(&self) -> String {
        let failed: Vec<&(String, bool, String)> =
            self.checks.iter().filter(|(_, ok, _)| !ok).collect();
        if failed.is_empty() {
            format!(
                "{} instructions, 100% ISA validation passed ({} checks)",
                self.instructions_checked,
                self.checks.len()
            )
        } else {
            format!(
                "VALIDATION FAILED: {}",
                failed
                    .iter()
                    .map(|(n, _, d)| format!("{n}: {d}"))
                    .collect::<Vec<_>>()
                    .join("; ")
            )
        }
    }

    pub fn into_result(self) -> Result<Report> {
        if self.passed() {
            Ok(self)
        } else {
            Err(Error::Validation(self.summary()))
        }
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.summary())
    }
}

/// ISA validation (paper: encoding correctness, register usage, immediate
/// ranges, instruction legality).
pub fn validate_isa(prog: &[Instr], mach: &MachineConfig) -> Report {
    let mut r = Report { instructions_checked: prog.len(), ..Default::default() };
    let legal: BTreeSet<Op> = Op::all().iter().copied().collect();

    // 1. Every opcode is one of the 61 legal instructions.
    let illegal: Vec<&Instr> = prog.iter().filter(|i| !legal.contains(&i.op)).collect();
    r.check("isa.legality", illegal.is_empty(), format!("{} illegal ops", illegal.len()));

    // 2. Vector instructions only on vector-capable targets.
    let uses_vector = prog.iter().any(|i| {
        matches!(format_of(i.op), Format::VArith | Format::VMem | Format::VSetF)
    });
    r.check(
        "isa.vector_capability",
        !uses_vector || mach.has_vector,
        format!("vector code on '{}' (has_vector={})", mach.name, mach.has_vector),
    );

    // 3. Immediate ranges + register ids via the encoder's checks.
    let mut bad_imm = 0usize;
    for i in prog {
        if encode::check_imm(i).is_err() {
            bad_imm += 1;
        }
    }
    r.check("isa.imm_ranges", bad_imm == 0, format!("{bad_imm} out-of-range immediates"));

    // 4. Encoding correctness: encode∘decode round-trips every instruction.
    let mut bad_rt = 0usize;
    for i in prog {
        match encode::encode(i) {
            Ok(w) => match decode::decode(w) {
                Ok(d) => {
                    if d.op != i.op {
                        bad_rt += 1;
                    }
                }
                Err(_) => bad_rt += 1,
            },
            Err(_) => bad_rt += 1,
        }
    }
    r.check("isa.encoding_roundtrip", bad_rt == 0, format!("{bad_rt} round-trip failures"));

    // 5. Register pressure within the three files (no spills possible).
    let p = regalloc::analyze_pressure(prog);
    r.check(
        "isa.register_pressure",
        p.int_regs <= 31 && p.float_regs <= 32 && p.vector_regs <= 32,
        format!("{p:?}"),
    );

    // 6. Branch targets land inside the program, on instruction boundaries.
    let mut bad_branch = 0usize;
    for (pos, i) in prog.iter().enumerate() {
        if matches!(format_of(i.op), Format::B | Format::J) {
            let target = pos as i64 + i.imm as i64 / 4;
            if i.imm % 4 != 0 || target < 0 || target > prog.len() as i64 {
                bad_branch += 1;
            }
        }
    }
    r.check("isa.branch_targets", bad_branch == 0, format!("{bad_branch} wild branches"));
    r
}

/// Memory validation (paper: DMEM/WMEM size limits, alignment, OOB).
pub fn validate_memory(g: &Graph, plan: &MemPlan, mach: &MachineConfig) -> Report {
    let mut r = Report::default();

    // 1. DMEM capacity.
    r.check(
        "mem.dmem_capacity",
        (plan.dmem_peak as usize) <= mach.dmem_bytes,
        format!("peak {} / {} bytes", plan.dmem_peak, mach.dmem_bytes),
    );

    // 2. WMEM capacity.
    r.check(
        "mem.wmem_capacity",
        (plan.wmem_used as usize) <= mach.wmem_bytes,
        format!("used {} / {} bytes", plan.wmem_used, mach.wmem_bytes),
    );

    // 3. Cache-line alignment of every placement base — scratch included
    //    (scratch regions come from the same allocator and kernels issue
    //    vector stores against them).
    let misaligned = plan
        .dmem
        .values()
        .chain(plan.wmem.values())
        .chain(plan.scratch.values())
        .filter(|p| p.addr % ALIGN != 0)
        .count();
    r.check("mem.alignment", misaligned == 0, format!("{misaligned} misaligned buffers"));

    // 3b. Element-width alignment: every base *and* extent is a multiple of
    //     the 4-byte staged element, so no word access can straddle a
    //     region boundary.
    let unaligned_elem = plan
        .dmem
        .values()
        .chain(plan.wmem.values())
        .chain(plan.scratch.values())
        .filter(|p| p.addr % 4 != 0 || p.bytes % 4 != 0)
        .count();
    r.check(
        "mem.element_alignment",
        unaligned_elem == 0,
        format!("{unaligned_elem} placements not 4-byte element aligned"),
    );

    // 4. Every graph tensor is placed (no dangling addresses -> no OOB from
    //    unplaced access).
    let mut unplaced = 0usize;
    for n in &g.nodes {
        for t in n.inputs.iter().chain(&n.outputs) {
            if plan.addr_of(*t).is_err() {
                unplaced += 1;
            }
        }
    }
    r.check("mem.all_placed", unplaced == 0, format!("{unplaced} unplaced tensors"));

    // 5. Placements stay within their regions (no buffer extends past
    //    capacity).
    let dmem_oob = plan
        .dmem
        .values()
        .filter(|p| (p.addr + p.bytes) as usize > mach.dmem_bytes)
        .count();
    let wmem_oob = plan
        .wmem
        .values()
        .filter(|p| (p.addr + p.bytes) as usize > mach.wmem_bytes)
        .count();
    let scratch_oob = plan
        .scratch
        .values()
        .filter(|p| (p.addr + p.bytes) as usize > mach.dmem_bytes)
        .count();
    r.check(
        "mem.bounds",
        dmem_oob == 0 && wmem_oob == 0 && scratch_oob == 0,
        format!(
            "{dmem_oob} DMEM / {wmem_oob} WMEM / {scratch_oob} scratch out-of-bounds buffers"
        ),
    );

    // 6. WMEM overlap discipline: content-hash dedup legitimately maps
    //    identical weights to the *exact same* placement; any other overlap
    //    is two live tensors clobbering each other. Distinct (addr, bytes)
    //    pairs must therefore be pairwise disjoint.
    let mut uniq: Vec<(u32, u32)> = plan.wmem.values().map(|p| (p.addr, p.bytes)).collect();
    uniq.sort_unstable();
    uniq.dedup();
    let mut accidental = 0usize;
    let mut prev_end = 0u64;
    for &(a, b) in &uniq {
        if (a as u64) < prev_end {
            accidental += 1;
        }
        prev_end = prev_end.max(a as u64 + b as u64);
    }
    r.check(
        "mem.wmem_overlap",
        accidental == 0,
        format!("{accidental} accidental (non-dedup) WMEM overlaps"),
    );
    r
}

/// ABI validation: the exported symbol table must cover the whole model
/// interface and describe addressable, non-overlapping buffers — a runtime
/// staging by it can never write outside the planned regions.
pub fn validate_abi(abi: &ModelAbi, g: &Graph, mach: &MachineConfig) -> Report {
    let mut r = Report::default();

    // 1. Coverage: every graph input and output has a symbol.
    let missing_in = g.inputs.len().saturating_sub(abi.inputs().count());
    let missing_out = g.outputs.len().saturating_sub(abi.outputs().count());
    r.check(
        "abi.io_coverage",
        missing_in == 0 && missing_out == 0,
        format!("{missing_in} inputs / {missing_out} outputs without symbols"),
    );

    // 2. Word alignment: every symbol is f32-addressable.
    let misaligned = abi.symbols.iter().filter(|s| s.addr % 4 != 0).count();
    r.check("abi.alignment", misaligned == 0, format!("{misaligned} misaligned symbols"));

    // 3. Region bounds: [addr, addr+bytes) stays inside DMEM resp. WMEM.
    let oob = abi
        .symbols
        .iter()
        .filter(|s| {
            let end = s.addr as u64 + s.bytes as u64;
            if s.addr >= layout::WMEM_BASE {
                end > layout::WMEM_BASE as u64 + mach.wmem_bytes as u64
            } else {
                end > layout::DMEM_BASE as u64 + mach.dmem_bytes as u64
            }
        })
        .count();
    r.check("abi.bounds", oob == 0, format!("{oob} symbols out of region bounds"));

    // 4. Distinct inputs never share storage (staging one must not clobber
    //    another).
    let ins: Vec<_> = abi.inputs().collect();
    let mut overlaps = 0usize;
    for (i, a) in ins.iter().enumerate() {
        for b in &ins[i + 1..] {
            let apart = a.addr as u64 + a.bytes as u64 <= b.addr as u64
                || b.addr as u64 + b.bytes as u64 <= a.addr as u64;
            if !apart {
                overlaps += 1;
            }
        }
    }
    r.check("abi.input_overlap", overlaps == 0, format!("{overlaps} overlapping input pairs"));
    r
}

/// Per-precision ABI checks (the quantized-datapath contract):
///
/// 1. **Staging width** — every weight symbol's extent covers f32-wide
///    elements. Kernels stride weights at 4 bytes/element on the functional
///    machine regardless of storage dtype; a symbol placed at quantized
///    width would make staged buffers overlap at runtime (the latent bug
///    PR 2 fixed for INT8, enforced here for every precision down to
///    Binary, whose deployed layout is bit-packed).
/// 2. **Storage dtype** — a quantized compile records its target precision
///    on every weight it quantized; a mismatch means some weight skipped
///    quantization (its bytes/PPA accounting would silently lie).
pub fn validate_precision(abi: &ModelAbi, g: &Graph, precision: crate::ir::DType) -> Report {
    let mut r = Report::default();
    let narrow = abi
        .weights()
        .filter(|s| (s.bytes as usize) < s.numel() * 4)
        .count();
    r.check(
        "abi.staging_width",
        narrow == 0,
        format!("{narrow} weight symbols narrower than f32 staging"),
    );
    let mismatched = if precision == crate::ir::DType::F32 {
        0
    } else {
        abi.weights()
            .filter(|s| {
                g.initializers
                    .get(&s.tensor)
                    .map(|i| i.dtype != precision)
                    .unwrap_or(false)
            })
            .count()
    };
    r.check(
        "abi.weight_dtype",
        mismatched == 0,
        format!("{mismatched} weights not stored at {}", precision.name()),
    );
    r
}

/// Static binary verification (see [`crate::analysis`]): encode the
/// program, recover its CFG, and run the abstract interpreter against the
/// memory plan's allocated regions — no instruction is executed.
pub fn validate_static(
    prog: &[Instr],
    plan: &MemPlan,
    mach: &MachineConfig,
) -> Result<crate::analysis::StaticReport> {
    let words = encode::encode_all(prog)?;
    let p = crate::sim::predecode::predecode(&words);
    let regions = crate::analysis::regions_of_plan(plan, mach);
    Ok(crate::analysis::analyze(&p, &regions, mach))
}

/// Fold a [`crate::analysis::StaticReport`] into validation check rows.
/// Error-level findings fail their category; Warn-level findings (the
/// honest "could not prove" degradations) never fail the compile gate but
/// surface in the coverage row's detail.
pub fn static_checks(sr: &crate::analysis::StaticReport) -> Vec<(String, bool, String)> {
    use crate::analysis::FindingCode as C;
    let cat = |codes: &[C]| -> (usize, String) {
        let mut n = 0usize;
        let mut first = String::new();
        for f in sr.error_findings() {
            if codes.contains(&f.code) {
                if n == 0 {
                    first = f.line();
                }
                n += 1;
            }
        }
        let detail = if n == 0 {
            "ok".to_string()
        } else if n == 1 {
            first
        } else {
            format!("{first} (+{} more)", n - 1)
        };
        (n, detail)
    };
    let (cfg_n, cfg_d) = cat(&[C::IllegalInstruction, C::MisalignedJump, C::WildJump]);
    let (mem_n, mem_d) = cat(&[C::OobAccess, C::MisalignedAccess]);
    let (du_n, du_d) = cat(&[C::UseBeforeDef]);
    vec![
        ("static.cfg".to_string(), cfg_n == 0, cfg_d),
        ("static.memory".to_string(), mem_n == 0, mem_d),
        ("static.defuse".to_string(), du_n == 0, du_d),
        ("static.coverage".to_string(), true, sr.summary()),
    ]
}

/// Full validation stage: ISA + memory, merged report.
pub fn validate_all(g: &Graph, prog: &[Instr], plan: &MemPlan, mach: &MachineConfig) -> Report {
    let mut r = validate_isa(prog, mach);
    let m = validate_memory(g, plan, mach);
    r.checks.extend(m.checks);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::memplan;
    use crate::codegen::graphgen::{self, Schedules};
    use crate::frontend::{model_zoo, prepare};
    use crate::ir::DType;
    use crate::isa::Instr;

    #[test]
    fn clean_program_passes_all_checks() {
        let g = prepare(model_zoo::resnet_cifar(1)).unwrap();
        let mach = MachineConfig::xgen_asic();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let prog = graphgen::lower_graph(&g, &mach, &plan, &Schedules::new(), DType::F32).unwrap();
        let r = validate_all(&g, &prog.asm, &plan, &mach);
        assert!(r.passed(), "{}", r.summary());
        assert!(r.summary().contains("100% ISA validation passed"));
    }

    #[test]
    fn rejects_vector_code_on_scalar_target() {
        let mut i = Instr::new(Op::Vsetvli);
        i.rd = 5;
        i.rs1 = 6;
        let r = validate_isa(&[i], &MachineConfig::cpu_a78());
        assert!(!r.passed());
        assert!(r.summary().contains("vector"));
    }

    #[test]
    fn rejects_bad_immediates() {
        let bad = Instr::i(Op::Addi, 1, 0, 40_000);
        let r = validate_isa(&[bad], &MachineConfig::xgen_asic());
        assert!(!r.passed());
    }

    #[test]
    fn rejects_wild_branches() {
        let bad = Instr::b(Op::Beq, 1, 2, -4096); // way before program start
        let r = validate_isa(&[bad], &MachineConfig::xgen_asic());
        assert!(!r.passed());
        assert!(r.checks.iter().any(|(n, ok, _)| n == "isa.branch_targets" && !ok));
    }

    #[test]
    fn abi_of_clean_compile_passes() {
        let g = prepare(model_zoo::mlp(&[32, 16, 8], 2)).unwrap();
        let mach = MachineConfig::xgen_asic();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let abi = plan.abi(&g).unwrap();
        let r = validate_abi(&abi, &g, &mach);
        assert!(r.passed(), "{}", r.summary());
    }

    #[test]
    fn abi_out_of_bounds_symbol_rejected() {
        let g = prepare(model_zoo::mlp(&[32, 16, 8], 1)).unwrap();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let mut abi = plan.abi(&g).unwrap();
        abi.symbols[0].addr = 1; // misaligned
        let mut tiny = MachineConfig::xgen_asic();
        tiny.dmem_bytes = 16;
        let r = validate_abi(&abi, &g, &tiny);
        assert!(!r.passed());
        assert!(r.checks.iter().any(|(n, ok, _)| n == "abi.alignment" && !ok));
        assert!(r.checks.iter().any(|(n, ok, _)| n == "abi.bounds" && !ok));
    }

    #[test]
    fn precision_checks_enforce_f32_staging_and_dtype() {
        let mut g = prepare(model_zoo::mlp(&[16, 8, 4], 1)).unwrap();
        crate::quant::ptq::quantize_graph(
            &mut g,
            DType::I4,
            crate::quant::calib::Method::MinMax,
            &[],
        )
        .unwrap();
        let mach = MachineConfig::xgen_asic();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let abi = plan.abi(&g).unwrap();
        let r = validate_precision(&abi, &g, DType::I4);
        assert!(r.passed(), "{}", r.summary());
        // A symbol shrunk to its quantized width must fail the gate.
        let mut bad = abi.clone();
        if let Some(w) = bad.symbols.iter_mut().find(|s| s.kind == memplan::SymKind::Weight) {
            w.bytes = (w.numel() / 2) as u32; // nibble-packed extent
        }
        let r = validate_precision(&bad, &g, DType::I4);
        assert!(!r.passed());
        assert!(r.checks.iter().any(|(n, ok, _)| n == "abi.staging_width" && !ok));
        // A weight left at the wrong storage dtype must fail too.
        let wid = *g.initializers.keys().next().unwrap();
        g.initializers.get_mut(&wid).unwrap().dtype = DType::F32;
        let r = validate_precision(&abi, &g, DType::I4);
        assert!(!r.passed());
        assert!(r.checks.iter().any(|(n, ok, _)| n == "abi.weight_dtype" && !ok));
    }

    #[test]
    fn dedup_wmem_overlap_is_legal_but_accidental_overlap_is_not() {
        let g = prepare(model_zoo::mlp(&[16, 8, 4], 1)).unwrap();
        let mach = MachineConfig::xgen_asic();
        let mut plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        // Exact-duplicate placement (content-hash dedup): legal.
        let (&first, &pl) = plan.wmem.iter().next().unwrap();
        let spare = crate::ir::TensorId(usize::MAX - 1);
        assert_ne!(first, spare);
        plan.wmem.insert(spare, pl);
        let r = validate_memory(&g, &plan, &mach);
        assert!(
            r.checks.iter().any(|(n, ok, _)| n == "mem.wmem_overlap" && *ok),
            "exact dedup aliasing must pass: {}",
            r.summary()
        );
        // Shifted partial overlap into the same extent: accidental, fails.
        plan.wmem.insert(spare, memplan::Placement { addr: pl.addr + 4, bytes: pl.bytes });
        let r = validate_memory(&g, &plan, &mach);
        assert!(r.checks.iter().any(|(n, ok, _)| n == "mem.wmem_overlap" && !ok));
    }

    #[test]
    fn element_misaligned_placement_is_rejected() {
        let g = prepare(model_zoo::mlp(&[16, 8, 4], 1)).unwrap();
        let mach = MachineConfig::xgen_asic();
        let mut plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let t = *plan.dmem.keys().next().unwrap();
        let pl = plan.dmem[&t];
        plan.dmem.insert(t, memplan::Placement { addr: pl.addr + 2, bytes: pl.bytes });
        let r = validate_memory(&g, &plan, &mach);
        assert!(r.checks.iter().any(|(n, ok, _)| n == "mem.element_alignment" && !ok));
        // A ragged extent (not a multiple of the element width) also fails.
        plan.dmem.insert(t, memplan::Placement { addr: pl.addr, bytes: pl.bytes + 1 });
        let r = validate_memory(&g, &plan, &mach);
        assert!(r.checks.iter().any(|(n, ok, _)| n == "mem.element_alignment" && !ok));
    }

    #[test]
    fn scratch_out_of_capacity_is_rejected() {
        let g = prepare(model_zoo::mlp(&[16, 8, 4], 1)).unwrap();
        let mut plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        plan.scratch.insert(
            crate::ir::NodeId(usize::MAX - 1),
            memplan::Placement { addr: u32::MAX - 256, bytes: 256 },
        );
        let mach = MachineConfig::xgen_asic();
        let r = validate_memory(&g, &plan, &mach);
        assert!(r.checks.iter().any(|(n, ok, _)| n == "mem.bounds" && !ok));
    }

    #[test]
    fn static_verifier_passes_a_clean_compile_and_bridges_checks() {
        let g = prepare(model_zoo::mlp(&[32, 16, 8], 2)).unwrap();
        let mach = MachineConfig::xgen_asic();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let prog = graphgen::lower_graph(&g, &mach, &plan, &Schedules::new(), DType::F32).unwrap();
        let sr = validate_static(&prog.asm, &plan, &mach).unwrap();
        assert!(sr.clean(), "{:?}", sr.findings);
        let rows = static_checks(&sr);
        assert!(rows.iter().all(|(_, ok, _)| *ok));
        assert!(rows.iter().any(|(n, _, _)| n == "static.coverage"));
    }

    #[test]
    fn memory_overflow_reported() {
        let g = prepare(model_zoo::mlp(&[512, 512, 512], 4)).unwrap();
        let plan = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let mut tiny = MachineConfig::xgen_asic();
        tiny.dmem_bytes = 1 << 10;
        let r = validate_memory(&g, &plan, &tiny);
        assert!(!r.passed());
        assert!(r.summary().contains("dmem_capacity"));
    }

    #[test]
    fn into_result_errors_on_failure() {
        let bad = Instr::i(Op::Addi, 1, 0, 99_999);
        let r = validate_isa(&[bad], &MachineConfig::xgen_asic());
        assert!(r.into_result().is_err());
    }
}
