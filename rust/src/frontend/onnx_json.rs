//! ONNX-JSON model format: a JSON projection of the ONNX GraphProto
//! (protobuf is unavailable offline; the JSON carries the same fields).
//!
//! ```json
//! {
//!   "name": "model",
//!   "inputs":  [{"name": "x", "shape": [1, 3, 224, 224], "dtype": "FP32"}],
//!   "outputs": ["logits"],
//!   "initializers": [
//!     {"name": "w1", "shape": [64, 3, 7, 7], "data": [..]},          // eager
//!     {"name": "w2", "shape": [64, 64, 3, 3], "seed": 7, "std": 0.02} // lazy
//!   ],
//!   "nodes": [
//!     {"op": "Conv", "name": "conv1", "inputs": ["x", "w1"],
//!      "outputs": ["a1"], "attrs": {"strides": [2, 2], "pads": [3, 3]}}
//!   ]
//! }
//! ```
//!
//! Symbolic dims are written as objects: `{"sym": "batch", "min": 1, "max": 32}`
//! or as `-1` (anonymous symbol, range 1..=64).
//!
//! The loader is hardened against malformed documents (fuzz satellite):
//! every tensor name may be defined exactly once (duplicate inputs,
//! initializers, or node outputs are typed `frontend:` errors), node inputs
//! must name an already-defined tensor — which makes loaded graphs DAGs by
//! construction, so a cyclic document cannot parse — and initializer shapes
//! are validated with overflow-checked element counts (zero or overflowing
//! extents are rejected instead of panicking downstream).

use std::collections::BTreeMap;

use crate::ir::dtype::DType;
use crate::ir::graph::{Graph, Node, TensorId};
use crate::ir::ops::{AttrValue, Attrs, OpKind};
use crate::ir::shape::{Dim, Shape};
use crate::ir::tensor::Initializer;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Load a model from an ONNX-JSON file.
pub fn load_file(path: &str) -> Result<Graph> {
    let text = std::fs::read_to_string(path)?;
    load_str(&text)
}

/// Load a model from ONNX-JSON text.
pub fn load_str(text: &str) -> Result<Graph> {
    let doc = Json::parse(text)?;
    let mut g = Graph::new(doc.get("name").as_str().unwrap_or("model"));
    // name -> tensor id map, populated as tensors appear.
    let mut by_name: BTreeMap<String, TensorId> = BTreeMap::new();

    for inp in doc.req_arr("inputs")? {
        let name = inp.req_str("name")?;
        let shape = parse_shape(inp.get("shape"))?;
        let dtype = inp
            .get("dtype")
            .as_str()
            .and_then(DType::parse)
            .unwrap_or(DType::F32);
        if by_name.contains_key(name) {
            return Err(Error::Frontend(format!("duplicate tensor name '{name}'")));
        }
        let id = g.input(name, shape, dtype);
        by_name.insert(name.to_string(), id);
    }

    if let Some(inits) = doc.get("initializers").as_arr() {
        for init in inits {
            let name = init.req_str("name")?;
            if by_name.contains_key(name) {
                return Err(Error::Frontend(format!("duplicate tensor name '{name}'")));
            }
            let dims: Vec<usize> = init
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| Error::Frontend("bad init dim".into())))
                .collect::<Result<_>>()?;
            // Overflow-checked element count: a hostile shape like
            // [2^32, 2^32] must become a typed error, not a downstream
            // panic or a zero-length allocation.
            let count = dims
                .iter()
                .try_fold(1usize, |acc, &d| if d == 0 { None } else { acc.checked_mul(d) })
                .ok_or_else(|| {
                    Error::Frontend(format!(
                        "initializer '{name}': invalid shape {dims:?} (zero or overflowing extent)"
                    ))
                })?;
            let mut i = if let Some(data) = init.get("data").as_arr() {
                let vals: Vec<f32> = data.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect();
                if vals.len() != count {
                    return Err(Error::Frontend(format!(
                        "initializer '{name}': {} values for shape {dims:?}",
                        vals.len()
                    )));
                }
                Initializer::eager(name, &dims, vals)
            } else {
                Initializer::lazy(
                    name,
                    &dims,
                    init.get("seed").as_i64().unwrap_or(0) as u64,
                    init.get("std").as_f64().unwrap_or(0.02) as f32,
                )
            };
            if let Some(dt) = init.get("dtype").as_str().and_then(DType::parse) {
                i.dtype = dt;
            }
            let id = g.init(i);
            by_name.insert(name.to_string(), id);
        }
    }

    for node in doc.req_arr("nodes")? {
        let op_name = node.req_str("op")?;
        let op = OpKind::parse(op_name).ok_or_else(|| {
            Error::Frontend(format!(
                "unsupported operator '{op_name}' (not in the {}-op registry)",
                OpKind::all().len()
            ))
        })?;
        let name = node.get("name").as_str().unwrap_or(op_name).to_string();
        let inputs: Vec<TensorId> = node
            .req_arr("inputs")?
            .iter()
            .map(|i| {
                let n = i
                    .as_str()
                    .ok_or_else(|| Error::Frontend("node input must be a name".into()))?;
                by_name
                    .get(n)
                    .copied()
                    .ok_or_else(|| Error::Frontend(format!("node '{name}' uses undefined tensor '{n}'")))
            })
            .collect::<Result<_>>()?;
        let out_names: Vec<String> = node
            .req_arr("outputs")?
            .iter()
            .map(|o| {
                o.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::Frontend("node output must be a name".into()))
            })
            .collect::<Result<_>>()?;
        // Outputs register only after this node's inputs resolved, so a
        // node can neither consume its own output nor a later node's:
        // loaded graphs are DAGs by construction.
        let outputs: Vec<TensorId> = out_names
            .iter()
            .map(|n| {
                if by_name.contains_key(n) {
                    return Err(Error::Frontend(format!(
                        "node '{name}' redefines tensor '{n}' (duplicate tensor name)"
                    )));
                }
                let id = g.tensor(n, None, DType::F32);
                by_name.insert(n.clone(), id);
                Ok(id)
            })
            .collect::<Result<_>>()?;
        g.nodes.push(Node {
            name,
            op,
            inputs,
            outputs,
            attrs: parse_attrs(node.get("attrs"))?,
        });
    }

    for out in doc.req_arr("outputs")? {
        let n = out
            .as_str()
            .ok_or_else(|| Error::Frontend("graph output must be a name".into()))?;
        let id = by_name
            .get(n)
            .copied()
            .ok_or_else(|| Error::Frontend(format!("undefined graph output '{n}'")))?;
        g.outputs.push(id);
    }
    Ok(g)
}

/// Serialize a graph back to ONNX-JSON (used by `dynshape` clone tests and
/// the CLI `export` command).
pub fn save_str(g: &Graph) -> String {
    let mut doc = BTreeMap::new();
    doc.insert("name".to_string(), Json::str_(&g.name));
    doc.insert(
        "inputs".to_string(),
        Json::Arr(
            g.inputs
                .iter()
                .map(|&id| {
                    let info = g.info(id);
                    Json::obj(vec![
                        ("name", Json::str_(&info.name)),
                        ("shape", shape_to_json(info.shape.as_ref().unwrap())),
                        ("dtype", Json::str_(info.dtype.name())),
                    ])
                })
                .collect(),
        ),
    );
    doc.insert(
        "outputs".to_string(),
        Json::Arr(
            g.outputs
                .iter()
                .map(|&id| Json::str_(&g.info(id).name))
                .collect(),
        ),
    );
    doc.insert(
        "initializers".to_string(),
        Json::Arr(
            g.initializers
                .iter()
                .map(|(_, init)| {
                    let mut fields = vec![
                        ("name", Json::str_(&init.name)),
                        (
                            "shape",
                            Json::Arr(
                                init.shape
                                    .dims()
                                    .iter()
                                    .map(|&d| Json::Num(d as f64))
                                    .collect(),
                            ),
                        ),
                        ("dtype", Json::str_(init.dtype.name())),
                    ];
                    match &init.data {
                        Some(t) => fields.push((
                            "data",
                            Json::Arr(t.data.iter().map(|&v| Json::Num(v as f64)).collect()),
                        )),
                        None => {
                            fields.push(("seed", Json::Num(init.seed as f64)));
                            fields.push(("std", Json::Num(init.init_std as f64)));
                        }
                    }
                    Json::obj(fields)
                })
                .collect(),
        ),
    );
    doc.insert(
        "nodes".to_string(),
        Json::Arr(
            g.nodes
                .iter()
                .map(|n| {
                    Json::obj(vec![
                        ("op", Json::str_(n.op.name())),
                        ("name", Json::str_(&n.name)),
                        (
                            "inputs",
                            Json::Arr(
                                n.inputs
                                    .iter()
                                    .map(|&t| Json::str_(&g.info(t).name))
                                    .collect(),
                            ),
                        ),
                        (
                            "outputs",
                            Json::Arr(
                                n.outputs
                                    .iter()
                                    .map(|&t| Json::str_(&g.info(t).name))
                                    .collect(),
                            ),
                        ),
                        ("attrs", attrs_to_json(&n.attrs)),
                    ])
                })
                .collect(),
        ),
    );
    Json::Obj(doc).to_string_pretty()
}

fn parse_shape(j: &Json) -> Result<Shape> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::Frontend("input shape must be an array".into()))?;
    let mut dims = Vec::new();
    for (i, d) in arr.iter().enumerate() {
        dims.push(match d {
            Json::Num(n) if *n == -1.0 => Dim::sym(&format!("dyn{i}"), 1, 64),
            Json::Num(n) if *n >= 1.0 => Dim::Fixed(*n as usize),
            Json::Obj(_) => {
                let name = d.req_str("sym")?;
                Dim::sym(
                    name,
                    d.get("min").as_usize().unwrap_or(1),
                    d.get("max").as_usize().unwrap_or(64),
                )
            }
            _ => return Err(Error::Frontend(format!("bad dim {d:?}"))),
        });
    }
    Ok(Shape(dims))
}

fn shape_to_json(s: &Shape) -> Json {
    Json::Arr(
        s.0.iter()
            .map(|d| match d {
                Dim::Fixed(n) => Json::Num(*n as f64),
                Dim::Sym { name, min, max } => Json::obj(vec![
                    ("sym", Json::str_(name)),
                    ("min", Json::Num(*min as f64)),
                    ("max", Json::Num(*max as f64)),
                ]),
            })
            .collect(),
    )
}

fn parse_attrs(j: &Json) -> Result<Attrs> {
    let mut attrs = Attrs::new();
    if let Some(obj) = j.as_obj() {
        for (k, v) in obj {
            let av = match v {
                Json::Num(n) if n.fract() == 0.0 => AttrValue::Int(*n as i64),
                Json::Num(n) => AttrValue::Float(*n),
                Json::Str(s) => AttrValue::Str(s.clone()),
                Json::Arr(a) => AttrValue::Ints(
                    a.iter()
                        .map(|x| {
                            x.as_i64()
                                .ok_or_else(|| Error::Frontend(format!("attr '{k}' bad int list")))
                        })
                        .collect::<Result<_>>()?,
                ),
                _ => return Err(Error::Frontend(format!("attr '{k}' unsupported value"))),
            };
            attrs.insert(k.clone(), av);
        }
    }
    Ok(attrs)
}

fn attrs_to_json(attrs: &Attrs) -> Json {
    Json::Obj(
        attrs
            .iter()
            .map(|(k, v)| {
                let j = match v {
                    AttrValue::Int(i) => Json::Num(*i as f64),
                    AttrValue::Float(f) => Json::Num(*f),
                    AttrValue::Str(s) => Json::str_(s),
                    AttrValue::Ints(v) => {
                        Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect())
                    }
                };
                (k.clone(), j)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tensor::Tensor;
    use crate::frontend::prepare;
    use crate::ir::exec::Executor;

    const TINY: &str = r#"{
        "name": "tiny",
        "inputs": [{"name": "x", "shape": [1, 4], "dtype": "FP32"}],
        "outputs": ["y"],
        "initializers": [
            {"name": "w", "shape": [4, 2], "data": [1,0, 0,1, 1,0, 0,1]}
        ],
        "nodes": [
            {"op": "MatMul", "name": "mm", "inputs": ["x", "w"], "outputs": ["h"]},
            {"op": "Relu", "name": "act", "inputs": ["h"], "outputs": ["y"]}
        ]
    }"#;

    #[test]
    fn load_infer_execute() {
        let g = prepare(load_str(TINY).unwrap()).unwrap();
        assert_eq!(g.nodes.len(), 2);
        let out = Executor::new()
            .run(&g, &[Tensor::new(vec![1, 4], vec![1.0, -2.0, 3.0, 4.0])])
            .unwrap();
        assert_eq!(out[0].data, vec![4.0, 2.0]);
    }

    #[test]
    fn roundtrip_through_save() {
        let g = prepare(load_str(TINY).unwrap()).unwrap();
        let text = save_str(&g);
        let g2 = prepare(load_str(&text).unwrap()).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        assert_eq!(g.param_count(), g2.param_count());
        let out = Executor::new()
            .run(&g2, &[Tensor::new(vec![1, 4], vec![1.0, -2.0, 3.0, 4.0])])
            .unwrap();
        assert_eq!(out[0].data, vec![4.0, 2.0]);
    }

    #[test]
    fn symbolic_dims_parse() {
        let text = r#"{
            "name": "dyn",
            "inputs": [{"name": "x", "shape": [{"sym": "batch", "min": 1, "max": 32}, 8]}],
            "outputs": ["y"],
            "initializers": [{"name": "w", "shape": [8, 8], "seed": 1, "std": 0.1}],
            "nodes": [{"op": "MatMul", "name": "mm", "inputs": ["x", "w"], "outputs": ["y"]}]
        }"#;
        let g = prepare(load_str(text).unwrap()).unwrap();
        assert!(g.has_symbolic_dims());
        assert_eq!(g.shape_of(g.outputs[0]).unwrap().onnx_dims(), vec![-1, 8]);
    }

    #[test]
    fn rejects_unknown_op() {
        let text = r#"{
            "name": "bad", "inputs": [{"name": "x", "shape": [1]}], "outputs": ["y"],
            "nodes": [{"op": "FrobnicateOp", "inputs": ["x"], "outputs": ["y"]}]
        }"#;
        let e = load_str(text).unwrap_err();
        assert!(format!("{e}").contains("FrobnicateOp"));
    }

    #[test]
    fn rejects_undefined_tensor() {
        let text = r#"{
            "name": "bad", "inputs": [{"name": "x", "shape": [1]}], "outputs": ["y"],
            "nodes": [{"op": "Relu", "inputs": ["ghost"], "outputs": ["y"]}]
        }"#;
        assert!(load_str(text).is_err());
    }

    #[test]
    fn rejects_duplicate_input_name() {
        let text = r#"{
            "name": "bad",
            "inputs": [{"name": "x", "shape": [1]}, {"name": "x", "shape": [2]}],
            "outputs": ["x"], "nodes": []
        }"#;
        let e = load_str(text).unwrap_err();
        assert!(format!("{e}").contains("duplicate tensor name 'x'"), "{e}");
    }

    #[test]
    fn rejects_initializer_shadowing_input() {
        let text = r#"{
            "name": "bad",
            "inputs": [{"name": "x", "shape": [1, 4]}],
            "outputs": ["x"],
            "initializers": [{"name": "x", "shape": [4], "seed": 1, "std": 0.1}],
            "nodes": []
        }"#;
        let e = load_str(text).unwrap_err();
        assert!(format!("{e}").contains("duplicate tensor name 'x'"), "{e}");
    }

    #[test]
    fn rejects_node_output_shadowing() {
        // A node output reusing an existing name would silently alias two
        // tensors — the shape this cycle/shadow takes in a JSON document.
        let text = r#"{
            "name": "bad", "inputs": [{"name": "x", "shape": [1, 4]}], "outputs": ["x"],
            "nodes": [{"op": "Relu", "inputs": ["x"], "outputs": ["x"]}]
        }"#;
        let e = load_str(text).unwrap_err();
        assert!(format!("{e}").contains("redefines tensor 'x'"), "{e}");
    }

    #[test]
    fn rejects_self_cycle() {
        // y is only defined by the node that also consumes it; at
        // input-resolution time it does not exist yet, so the cycle
        // surfaces as an undefined-tensor error.
        let text = r#"{
            "name": "bad", "inputs": [{"name": "x", "shape": [1, 4]}], "outputs": ["y"],
            "nodes": [{"op": "Add", "inputs": ["y", "x"], "outputs": ["y"]}]
        }"#;
        let e = load_str(text).unwrap_err();
        assert!(format!("{e}").contains("undefined tensor 'y'"), "{e}");
    }

    #[test]
    fn rejects_degenerate_initializer_shapes() {
        // 2^32 x 2^32 overflows the 64-bit element count; a zero extent is
        // an empty weight. Both must be typed errors, not panics.
        for shape in ["[4294967296, 4294967296]", "[0, 4]"] {
            let text = format!(
                r#"{{
                    "name": "bad", "inputs": [{{"name": "x", "shape": [1]}}], "outputs": ["x"],
                    "initializers": [{{"name": "w", "shape": {shape}, "seed": 1, "std": 0.1}}],
                    "nodes": []
                }}"#
            );
            let e = load_str(&text).unwrap_err();
            assert!(
                format!("{e}").contains("zero or overflowing extent"),
                "shape {shape}: {e}"
            );
        }
    }
}
