//! The paper's evaluation models (§4.1), built programmatically at full
//! scale with deterministic lazily-synthesized weights: ResNet-50,
//! MobileNet-V2, BERT-base, ViT-Base — plus CIFAR-scale variants used by the
//! execution-heavy experiments (quantization accuracy, codegen numerics) and
//! the three-model vision-language pipeline of case study 1.

use crate::ir::dtype::DType;
use crate::ir::graph::{Graph, TensorId};
use crate::ir::ops::{AttrValue, Attrs, OpKind};
use crate::ir::shape::{Dim, Shape};
use crate::ir::tensor::Initializer;
use crate::util::error::{Error, Result};

/// Look up a zoo model by name.
pub fn by_name(name: &str) -> Result<Graph> {
    Ok(match name {
        "resnet50" => resnet50(1),
        "mobilenet_v2" => mobilenet_v2(1),
        "bert_base" => bert_base(1, 128),
        "vit_base" => vit_base(1),
        "resnet_cifar" => resnet_cifar(1),
        "mobilenet_cifar" => mobilenet_cifar(1),
        "bert_tiny" => bert_tiny(1, 32),
        "vit_tiny" => vit_tiny(1),
        "mlp" => mlp(&[256, 128, 64, 10], 1),
        "vision_encoder" => vision_encoder(1),
        "text_encoder" => text_encoder(1, 64),
        "decoder" => decoder(1, 64),
        other => {
            return Err(Error::Frontend(format!(
                "unknown zoo model '{other}' (try resnet50, mobilenet_v2, bert_base, vit_base, \
                 resnet_cifar, mobilenet_cifar, bert_tiny, vit_tiny, mlp)"
            )))
        }
    })
}

/// The paper's four evaluation models (Table 3 rows).
pub fn paper_models() -> Vec<(&'static str, Graph)> {
    vec![
        ("ResNet-50", resnet50(1)),
        ("MobileNet-V2", mobilenet_v2(1)),
        ("BERT-base", bert_base(1, 128)),
        ("ViT-Base", vit_base(1)),
    ]
}

// ---------------------------------------------------------------------------
// builder helpers
// ---------------------------------------------------------------------------

/// Weight-seed counter so every initializer in a model gets a distinct,
/// deterministic seed.
struct Seeder(u64);

impl Seeder {
    fn next(&mut self) -> u64 {
        self.0 += 1;
        self.0
    }
}

fn attrs(kv: &[(&str, AttrValue)]) -> Attrs {
    kv.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

fn ints(v: &[i64]) -> AttrValue {
    AttrValue::Ints(v.to_vec())
}

/// Conv (+ optional BN folded as scale/bias conv channel params) + ReLU.
#[allow(clippy::too_many_arguments)]
fn conv_bn_act(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    act: Option<OpKind>,
) -> TensorId {
    let std = (2.0 / (cin * k * k) as f32).sqrt(); // He init
    let w = g.init(Initializer::lazy(
        &format!("{name}_w"),
        &[cout, cin, k, k],
        s.next(),
        std,
    ));
    let b = g.init(Initializer::lazy(&format!("{name}_b"), &[cout], s.next(), 0.01));
    let mut y = g.node(
        OpKind::Conv,
        name,
        &[x, w, b],
        attrs(&[
            ("strides", ints(&[stride as i64, stride as i64])),
            ("pads", ints(&[pad as i64, pad as i64])),
        ]),
    );
    // BatchNorm (inference form). Folded params still exercise the real op.
    let gamma = g.init(Initializer::lazy(&format!("{name}_bn_g"), &[cout], s.next(), 0.1));
    let beta = g.init(Initializer::lazy(&format!("{name}_bn_b"), &[cout], s.next(), 0.01));
    let mean = g.init(Initializer::lazy(&format!("{name}_bn_m"), &[cout], s.next(), 0.01));
    let var = g.init(Initializer::eager(
        &format!("{name}_bn_v"),
        &[cout],
        vec![1.0; cout],
    ));
    y = g.node(
        OpKind::BatchNormalization,
        &format!("{name}_bn"),
        &[y, gamma, beta, mean, var],
        Attrs::new(),
    );
    match act {
        Some(op) => g.node(op, &format!("{name}_act"), &[y], Attrs::new()),
        None => y,
    }
}

fn depthwise_bn_act(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> TensorId {
    let std = (2.0 / (k * k) as f32).sqrt();
    let w = g.init(Initializer::lazy(&format!("{name}_w"), &[c, 1, k, k], s.next(), std));
    let y = g.node(
        OpKind::DepthwiseConv,
        name,
        &[x, w],
        attrs(&[
            ("strides", ints(&[stride as i64, stride as i64])),
            ("pads", ints(&[pad as i64, pad as i64])),
        ]),
    );
    g.node(OpKind::Relu6, &format!("{name}_act"), &[y], Attrs::new())
}

fn fc(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    din: usize,
    dout: usize,
) -> TensorId {
    let std = (2.0 / din as f32).sqrt();
    let w = g.init(Initializer::lazy(&format!("{name}_w"), &[din, dout], s.next(), std));
    let b = g.init(Initializer::lazy(&format!("{name}_b"), &[dout], s.next(), 0.01));
    g.node(OpKind::Gemm, name, &[x, w, b], Attrs::new())
}

// ---------------------------------------------------------------------------
// MLP family (compile-time scaling experiments, quickstart)
// ---------------------------------------------------------------------------

/// Plain MLP: sizes[0] -> ... -> sizes[last], ReLU between layers.
pub fn mlp(sizes: &[usize], batch: usize) -> Graph {
    let mut g = Graph::new("mlp");
    let mut s = Seeder(1000);
    let mut x = g.input("x", Shape::fixed(&[batch, sizes[0]]), DType::F32);
    for (i, w) in sizes.windows(2).enumerate() {
        x = fc(&mut g, &mut s, &format!("fc{i}"), x, w[0], w[1]);
        if i + 2 < sizes.len() {
            x = g.node(OpKind::Relu, &format!("relu{i}"), &[x], Attrs::new());
        }
    }
    g.outputs.push(x);
    g
}

/// MLP with a symbolic batch dimension (dynamic-shape experiments, §3.5).
pub fn mlp_dynamic(sizes: &[usize], max_batch: usize) -> Graph {
    let mut g = Graph::new("mlp_dyn");
    let mut s = Seeder(1000);
    let mut x = g.input(
        "x",
        Shape(vec![Dim::sym("batch", 1, max_batch), Dim::Fixed(sizes[0])]),
        DType::F32,
    );
    for (i, w) in sizes.windows(2).enumerate() {
        x = fc(&mut g, &mut s, &format!("fc{i}"), x, w[0], w[1]);
        if i + 2 < sizes.len() {
            x = g.node(OpKind::Relu, &format!("relu{i}"), &[x], Attrs::new());
        }
    }
    g.outputs.push(x);
    g
}

// ---------------------------------------------------------------------------
// ResNet-50 (paper scale: 224x224, ~25.5M params)
// ---------------------------------------------------------------------------

fn bottleneck(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    cin: usize,
    cmid: usize,
    cout: usize,
    stride: usize,
) -> TensorId {
    let a = conv_bn_act(g, s, &format!("{name}_c1"), x, cin, cmid, 1, 1, 0, Some(OpKind::Relu));
    let b = conv_bn_act(g, s, &format!("{name}_c2"), a, cmid, cmid, 3, stride, 1, Some(OpKind::Relu));
    let c = conv_bn_act(g, s, &format!("{name}_c3"), b, cmid, cout, 1, 1, 0, None);
    let shortcut = if cin != cout || stride != 1 {
        conv_bn_act(g, s, &format!("{name}_sc"), x, cin, cout, 1, stride, 0, None)
    } else {
        x
    };
    let sum = g.node(OpKind::Add, &format!("{name}_add"), &[c, shortcut], Attrs::new());
    g.node(OpKind::Relu, &format!("{name}_out"), &[sum], Attrs::new())
}

fn resnet(name: &str, batch: usize, img: usize, blocks: [usize; 4], width: usize, classes: usize) -> Graph {
    let mut g = Graph::new(name);
    let mut s = Seeder(2000);
    let x = g.input("image", Shape::fixed(&[batch, 3, img, img]), DType::F32);
    // Stem.
    let mut y = conv_bn_act(&mut g, &mut s, "conv1", x, 3, width, 7, 2, 3, Some(OpKind::Relu));
    y = g.node(
        OpKind::MaxPool,
        "pool1",
        &[y],
        attrs(&[
            ("kernel_shape", ints(&[3, 3])),
            ("strides", ints(&[2, 2])),
            ("pads", ints(&[1, 1])),
        ]),
    );
    // Stages.
    let mut cin = width;
    for (si, &n) in blocks.iter().enumerate() {
        let cmid = width << si;
        let cout = cmid * 4;
        for bi in 0..n {
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            y = bottleneck(&mut g, &mut s, &format!("s{si}b{bi}"), y, cin, cmid, cout, stride);
            cin = cout;
        }
    }
    // Head.
    y = g.node(OpKind::GlobalAveragePool, "gap", &[y], Attrs::new());
    y = g.node(
        OpKind::Flatten,
        "flat",
        &[y],
        attrs(&[("axis", AttrValue::Int(1))]),
    );
    y = fc(&mut g, &mut s, "fc", y, cin, classes);
    g.outputs.push(y);
    g
}

/// Full ResNet-50 @ 224 (paper Table 3 row 1).
pub fn resnet50(batch: usize) -> Graph {
    resnet("resnet50", batch, 224, [3, 4, 6, 3], 64, 1000)
}

/// CIFAR-scale ResNet (32x32, narrow) — executable on the host oracle for
/// the Table 6 accuracy-retention experiments.
pub fn resnet_cifar(batch: usize) -> Graph {
    resnet("resnet_cifar", batch, 32, [1, 1, 1, 1], 16, 10)
}

// ---------------------------------------------------------------------------
// MobileNet-V2 (paper scale: ~3.5M params)
// ---------------------------------------------------------------------------

fn inverted_residual(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    cin: usize,
    cout: usize,
    stride: usize,
    expand: usize,
) -> TensorId {
    let cexp = cin * expand;
    let mut y = x;
    if expand != 1 {
        y = conv_bn_act(g, s, &format!("{name}_exp"), y, cin, cexp, 1, 1, 0, Some(OpKind::Relu6));
    }
    y = depthwise_bn_act(g, s, &format!("{name}_dw"), y, cexp, 3, stride, 1);
    y = conv_bn_act(g, s, &format!("{name}_proj"), y, cexp, cout, 1, 1, 0, None);
    if stride == 1 && cin == cout {
        y = g.node(OpKind::Add, &format!("{name}_res"), &[y, x], Attrs::new());
    }
    y
}

fn mobilenet(name: &str, batch: usize, img: usize, width_mult: f32, classes: usize) -> Graph {
    let mut g = Graph::new(name);
    let mut s = Seeder(3000);
    let scale = |c: usize| ((c as f32 * width_mult) as usize).max(8);
    let x = g.input("image", Shape::fixed(&[batch, 3, img, img]), DType::F32);
    let mut c = scale(32);
    let mut y = conv_bn_act(&mut g, &mut s, "conv1", x, 3, c, 3, 2, 1, Some(OpKind::Relu6));
    // (expand, channels, repeats, stride) — the MobileNet-V2 spec table.
    let spec: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for (bi, &(t, ch, n, st)) in spec.iter().enumerate() {
        let cout = scale(ch);
        for i in 0..n {
            let stride = if i == 0 { st } else { 1 };
            y = inverted_residual(&mut g, &mut s, &format!("ir{bi}_{i}"), y, c, cout, stride, t);
            c = cout;
        }
    }
    let clast = scale(1280);
    y = conv_bn_act(&mut g, &mut s, "conv_last", y, c, clast, 1, 1, 0, Some(OpKind::Relu6));
    y = g.node(OpKind::GlobalAveragePool, "gap", &[y], Attrs::new());
    y = g.node(OpKind::Flatten, "flat", &[y], attrs(&[("axis", AttrValue::Int(1))]));
    y = fc(&mut g, &mut s, "fc", y, clast, classes);
    g.outputs.push(y);
    g
}

/// Full MobileNet-V2 @ 224 (paper Table 3 row 2).
pub fn mobilenet_v2(batch: usize) -> Graph {
    mobilenet("mobilenet_v2", batch, 224, 1.0, 1000)
}

/// CIFAR-scale MobileNet (32x32, 0.5x width) for accuracy experiments.
pub fn mobilenet_cifar(batch: usize) -> Graph {
    mobilenet("mobilenet_cifar", batch, 32, 0.5, 10)
}

// ---------------------------------------------------------------------------
// Transformers: BERT-base & ViT-Base (~110M / ~86M params)
// ---------------------------------------------------------------------------

fn transformer_layer(
    g: &mut Graph,
    s: &mut Seeder,
    name: &str,
    x: TensorId,
    d: usize,
    ffn: usize,
    heads: usize,
    seq: usize,
    batch: usize,
) -> TensorId {
    let mk = |g: &mut Graph, s: &mut Seeder, n: String| {
        let std = (1.0 / d as f32).sqrt();
        g.init(Initializer::lazy(&n, &[d, d], s.next(), std))
    };
    let wq = mk(g, s, format!("{name}_wq"));
    let wk = mk(g, s, format!("{name}_wk"));
    let wv = mk(g, s, format!("{name}_wv"));
    let wo = mk(g, s, format!("{name}_wo"));
    let attn = g.node(
        OpKind::Attention,
        &format!("{name}_attn"),
        &[x, wq, wk, wv, wo],
        attrs(&[("num_heads", AttrValue::Int(heads as i64))]),
    );
    let res1 = g.node(OpKind::Add, &format!("{name}_res1"), &[x, attn], Attrs::new());
    let ln_g = g.init(Initializer::eager(&format!("{name}_ln1_g"), &[d], vec![1.0; d]));
    let ln_b = g.init(Initializer::eager(&format!("{name}_ln1_b"), &[d], vec![0.0; d]));
    let ln1 = g.node(
        OpKind::LayerNormalization,
        &format!("{name}_ln1"),
        &[res1, ln_g, ln_b],
        Attrs::new(),
    );
    // FFN: reshape to 2-D for Gemm, then back.
    let flat = g.node(
        OpKind::Reshape,
        &format!("{name}_flat"),
        &[ln1],
        attrs(&[("shape", ints(&[(batch * seq) as i64, d as i64]))]),
    );
    let h = fc(g, s, &format!("{name}_ffn1"), flat, d, ffn);
    let h = g.node(OpKind::Gelu, &format!("{name}_gelu"), &[h], Attrs::new());
    let h = fc(g, s, &format!("{name}_ffn2"), h, ffn, d);
    let unflat = g.node(
        OpKind::Reshape,
        &format!("{name}_unflat"),
        &[h],
        attrs(&[("shape", ints(&[batch as i64, seq as i64, d as i64]))]),
    );
    let res2 = g.node(OpKind::Add, &format!("{name}_res2"), &[ln1, unflat], Attrs::new());
    let ln2_g = g.init(Initializer::eager(&format!("{name}_ln2_g"), &[d], vec![1.0; d]));
    let ln2_b = g.init(Initializer::eager(&format!("{name}_ln2_b"), &[d], vec![0.0; d]));
    g.node(
        OpKind::LayerNormalization,
        &format!("{name}_ln2"),
        &[res2, ln2_g, ln2_b],
        Attrs::new(),
    )
}

fn bert(name: &str, batch: usize, seq: usize, d: usize, layers: usize, heads: usize, vocab: usize) -> Graph {
    let mut g = Graph::new(name);
    let mut s = Seeder(4000);
    let emb = g.init(Initializer::lazy("tok_emb", &[vocab, d], s.next(), 0.02));
    let ids = g.input("input_ids", Shape::fixed(&[batch, seq]), DType::I32);
    let mut x = g.node(OpKind::Gather, "embed", &[emb, ids], Attrs::new());
    let pos = g.init(Initializer::lazy("pos_emb", &[seq, d], s.next(), 0.02));
    x = g.node(OpKind::Add, "pos_add", &[x, pos], Attrs::new());
    for l in 0..layers {
        x = transformer_layer(&mut g, &mut s, &format!("l{l}"), x, d, d * 4, heads, seq, batch);
    }
    // Pooler over [CLS]-equivalent: mean-pool then dense+tanh.
    let pooled = g.node(
        OpKind::ReduceMean,
        "pool",
        &[x],
        attrs(&[("axes", ints(&[1])), ("keepdims", AttrValue::Int(0))]),
    );
    let y = fc(&mut g, &mut s, "pooler", pooled, d, d);
    let y = g.node(OpKind::Tanh, "pooler_act", &[y], Attrs::new());
    g.outputs.push(y);
    g
}

/// Full BERT-base: 12 layers, d=768, 12 heads, vocab 30522 (Table 3 row 3).
pub fn bert_base(batch: usize, seq: usize) -> Graph {
    bert("bert_base", batch, seq, 768, 12, 12, 30522)
}

/// Tiny BERT for execution experiments: 2 layers, d=64.
pub fn bert_tiny(batch: usize, seq: usize) -> Graph {
    bert("bert_tiny", batch, seq, 64, 2, 4, 1000)
}

fn vit(name: &str, batch: usize, img: usize, patch: usize, d: usize, layers: usize, heads: usize, classes: usize) -> Graph {
    let mut g = Graph::new(name);
    let mut s = Seeder(5000);
    let x = g.input("image", Shape::fixed(&[batch, 3, img, img]), DType::F32);
    // Patch embedding: conv patch x patch stride patch -> [B, D, P, P].
    let std = (2.0 / (3 * patch * patch) as f32).sqrt();
    let w = g.init(Initializer::lazy("patch_w", &[d, 3, patch, patch], s.next(), std));
    let mut y = g.node(
        OpKind::Conv,
        "patch_embed",
        &[x, w],
        attrs(&[("strides", ints(&[patch as i64, patch as i64]))]),
    );
    let p = img / patch;
    let seq = p * p;
    // [B, D, P, P] -> [B, D, S] -> [B, S, D]
    y = g.node(
        OpKind::Reshape,
        "tokens",
        &[y],
        attrs(&[("shape", ints(&[batch as i64, d as i64, seq as i64]))]),
    );
    y = g.node(
        OpKind::Transpose,
        "tokens_t",
        &[y],
        attrs(&[("perm", ints(&[0, 2, 1]))]),
    );
    let pos = g.init(Initializer::lazy("pos_emb", &[seq, d], s.next(), 0.02));
    y = g.node(OpKind::Add, "pos_add", &[y, pos], Attrs::new());
    for l in 0..layers {
        y = transformer_layer(&mut g, &mut s, &format!("l{l}"), y, d, d * 4, heads, seq, batch);
    }
    let pooled = g.node(
        OpKind::ReduceMean,
        "pool",
        &[y],
        attrs(&[("axes", ints(&[1])), ("keepdims", AttrValue::Int(0))]),
    );
    let logits = fc(&mut g, &mut s, "head", pooled, d, classes);
    g.outputs.push(logits);
    g
}

/// Full ViT-Base/16 @ 224 (Table 3 row 4).
pub fn vit_base(batch: usize) -> Graph {
    vit("vit_base", batch, 224, 16, 768, 12, 12, 1000)
}

/// Tiny ViT for execution experiments.
pub fn vit_tiny(batch: usize) -> Graph {
    vit("vit_tiny", batch, 32, 8, 64, 2, 4, 10)
}

// ---------------------------------------------------------------------------
// Case study 1: vision-language pipeline (vision enc + text enc + decoder)
// ---------------------------------------------------------------------------

/// Vision encoder: a ViT-Large-width tower. Together the three pipeline
/// models carry ~1.25 GB of raw FP32 weights; WMEM consolidation (§5.1)
/// dedups the text-encoder/decoder shared layers down to ~980 MB — the case
/// study's numbers.
pub fn vision_encoder(batch: usize) -> Graph {
    vit("vision_encoder", batch, 224, 14, 1024, 12, 16, 1024)
}

/// Text encoder: BERT-like, 6 layers at d=768.
pub fn text_encoder(batch: usize, seq: usize) -> Graph {
    bert("text_encoder", batch, seq, 768, 6, 12, 30522)
}

/// Decoder: GPT-like, 10 layers at d=768. Initialized *from the text
/// encoder* (common VLM practice), so its embedding table and first six
/// layers are bit-identical to `text_encoder`'s — which is exactly what
/// WMEM consolidation exploits (both builders share the same seed stream).
pub fn decoder(batch: usize, seq: usize) -> Graph {
    bert("decoder", batch, seq, 768, 10, 12, 30522)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::prepare;
    use crate::ir::exec::Executor;
    use crate::ir::tensor::Tensor;

    #[test]
    fn resnet50_paper_scale() {
        let g = prepare(resnet50(1)).unwrap();
        let params = g.param_count();
        // Torch ResNet-50: 25.56M. Ours (conv+bn+fc) should land close.
        assert!(
            (23_000_000..28_000_000).contains(&params),
            "resnet50 params {params}"
        );
        // Output logits [1, 1000].
        assert_eq!(
            g.shape_of(g.outputs[0]).unwrap().dims(),
            vec![1, 1000]
        );
    }

    #[test]
    fn mobilenet_v2_paper_scale() {
        let g = prepare(mobilenet_v2(1)).unwrap();
        let params = g.param_count();
        // Torch MobileNet-V2: 3.5M.
        assert!(
            (2_500_000..5_000_000).contains(&params),
            "mobilenet params {params}"
        );
    }

    #[test]
    fn bert_base_paper_scale() {
        let g = prepare(bert_base(1, 128)).unwrap();
        let params = g.param_count();
        // BERT-base: ~110M.
        assert!(
            (95_000_000..125_000_000).contains(&params),
            "bert params {params}"
        );
        assert_eq!(g.shape_of(g.outputs[0]).unwrap().dims(), vec![1, 768]);
    }

    #[test]
    fn vit_base_paper_scale() {
        let g = prepare(vit_base(1)).unwrap();
        let params = g.param_count();
        // ViT-Base: ~86M.
        assert!(
            (75_000_000..95_000_000).contains(&params),
            "vit params {params}"
        );
    }

    #[test]
    fn cifar_variants_execute() {
        let g = prepare(resnet_cifar(1)).unwrap();
        let out = Executor::new()
            .run(&g, &[Tensor::zeros(&[1, 3, 32, 32])])
            .unwrap();
        assert_eq!(out[0].shape, vec![1, 10]);

        let g = prepare(mobilenet_cifar(1)).unwrap();
        let out = Executor::new()
            .run(&g, &[Tensor::zeros(&[1, 3, 32, 32])])
            .unwrap();
        assert_eq!(out[0].shape, vec![1, 10]);
    }

    #[test]
    fn bert_tiny_executes() {
        let g = prepare(bert_tiny(1, 32)).unwrap();
        let ids = Tensor::new(vec![1, 32], (0..32).map(|i| (i % 100) as f32).collect());
        let out = Executor::new().run(&g, &[ids]).unwrap();
        assert_eq!(out[0].shape, vec![1, 64]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn vit_tiny_executes() {
        let g = prepare(vit_tiny(1)).unwrap();
        let mut img = Tensor::zeros(&[1, 3, 32, 32]);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = ((i % 17) as f32 - 8.0) / 8.0;
        }
        let out = Executor::new().run(&g, &[img]).unwrap();
        assert_eq!(out[0].shape, vec![1, 10]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pipeline_models_total_near_980mb() {
        // Case study 1: 3 models, ~980MB of FP32 weights after consolidation.
        let total: usize = [vision_encoder(1), text_encoder(1, 64), decoder(1, 64)]
            .iter()
            .map(|g| g.weight_bytes())
            .sum();
        let mb = total as f64 / (1024.0 * 1024.0);
        assert!((700.0..1400.0).contains(&mb), "pipeline weights {mb:.0} MB");
    }

    #[test]
    fn zoo_by_name_dispatch() {
        assert!(by_name("resnet50").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn deterministic_weights() {
        let a = resnet_cifar(1);
        let b = resnet_cifar(1);
        let ia = a.initializers.values().next().unwrap();
        let ib = b.initializers.values().next().unwrap();
        assert_eq!(ia.materialize(), ib.materialize());
    }
}
