//! Frontend (paper §3.1 stage 1): model loading and IR construction.
//!
//! Models arrive either as ONNX-JSON files ([`onnx_json`]) or from the
//! built-in [`model_zoo`] (the paper's four evaluation models at full scale,
//! plus scaled variants for execution-heavy experiments). After loading,
//! shape inference annotates every tensor and `Graph::check` enforces
//! structural validity — nothing undefined proceeds to optimization.

pub mod model_zoo;
pub mod onnx_json;

use crate::ir::{infer, Graph};
use crate::util::error::Result;

/// Load + validate + infer shapes: the complete frontend stage.
pub fn prepare(mut g: Graph) -> Result<Graph> {
    g.check()?;
    infer::infer_shapes(&mut g)?;
    Ok(g)
}

/// Resolve a model spec: `zoo:<name>` or a path to an ONNX-JSON file.
pub fn load_model(spec: &str) -> Result<Graph> {
    let g = if let Some(name) = spec.strip_prefix("zoo:") {
        model_zoo::by_name(name)?
    } else {
        onnx_json::load_file(spec)?
    };
    prepare(g)
}
