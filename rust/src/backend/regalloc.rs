//! Register allocation: linear-scan for virtual-register programs and a
//! pressure analyzer used by validation.
//!
//! The kernel library emits statically-allocated code (fixed conventions in
//! `isa::regs`), so the allocator's production role is *verification* — the
//! validator proves no kernel exceeds the register files — plus remapping
//! for programs authored with virtual registers (ids >= 32), which the
//! scheduler's tests and future fused kernels use.

use std::collections::BTreeMap;

use crate::isa::encode::{format_of, Format};
use crate::isa::{Instr, Op};
use crate::util::error::{Error, Result};

/// Whether an operand field of this op refers to the float register file.
fn reads_float(op: Op) -> bool {
    matches!(
        op.class(),
        crate::isa::OpClass::FAlu
            | crate::isa::OpClass::FMul
            | crate::isa::OpClass::FDiv
            | crate::isa::OpClass::FMa
            | crate::isa::OpClass::FCustom
    )
}

/// Peak simultaneous register usage (distinct registers referenced), per
/// file. Conservative: treats every referenced register as live for the
/// whole program — an upper bound that the 61-op kernels stay well under.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Pressure {
    pub int_regs: usize,
    pub float_regs: usize,
    pub vector_regs: usize,
}

pub fn analyze_pressure(prog: &[Instr]) -> Pressure {
    let mut xs = std::collections::BTreeSet::new();
    let mut fs = std::collections::BTreeSet::new();
    let mut vs = std::collections::BTreeSet::new();
    for i in prog {
        match format_of(i.op) {
            Format::VArith | Format::VMem => {
                vs.insert(i.rd);
                if i.op != Op::VfmvVF && i.op != Op::VfmaccVF {
                    vs.insert(i.rs1);
                }
                vs.insert(i.rs2);
                if matches!(i.op, Op::VfmaccVF | Op::VfmvVF) {
                    fs.insert(i.rs1);
                }
                if format_of(i.op) == Format::VMem {
                    xs.insert(i.rs1);
                }
            }
            Format::VSetF => {
                xs.insert(i.rd);
                xs.insert(i.rs1);
            }
            _ if reads_float(i.op) => {
                fs.insert(i.rd);
                fs.insert(i.rs1);
                fs.insert(i.rs2);
                if format_of(i.op) == Format::R4 {
                    fs.insert(i.rs3);
                }
                if matches!(i.op, Op::FcvtWS) {
                    xs.insert(i.rd);
                    fs.remove(&i.rd);
                }
                if matches!(i.op, Op::FcvtSW) {
                    xs.insert(i.rs1);
                    fs.remove(&i.rs1);
                }
            }
            Format::S => {
                xs.insert(i.rs1);
                if i.op == Op::Fsw {
                    fs.insert(i.rs2);
                } else {
                    xs.insert(i.rs2);
                }
            }
            Format::I if i.op == Op::Flw => {
                fs.insert(i.rd);
                xs.insert(i.rs1);
            }
            _ => {
                xs.insert(i.rd);
                xs.insert(i.rs1);
                xs.insert(i.rs2);
            }
        }
    }
    xs.remove(&0); // x0 is free
    Pressure { int_regs: xs.len(), float_regs: fs.len(), vector_regs: vs.len() }
}

/// Linear-scan allocation for programs using virtual integer registers
/// (ids >= 32). Physical t/s registers are assigned by live range; programs
/// needing more simultaneous lives than available registers are rejected
/// (the caller must spill — generated kernels never hit this by
/// construction, and validation would refuse them).
pub fn linear_scan(prog: &[Instr]) -> Result<Vec<Instr>> {
    // Live ranges of virtual regs.
    let mut first: BTreeMap<u8, usize> = BTreeMap::new();
    let mut last: BTreeMap<u8, usize> = BTreeMap::new();
    for (pos, i) in prog.iter().enumerate() {
        for r in [i.rd, i.rs1, i.rs2, i.rs3] {
            if r >= 32 {
                first.entry(r).or_insert(pos);
                last.insert(r, pos);
            }
        }
    }
    // Allocatable pool: t0-t6, s2-s11 (avoid args/sp/ra).
    const POOL: [u8; 17] = [5, 6, 7, 28, 29, 30, 31, 18, 19, 20, 21, 22, 23, 24, 25, 26, 27];
    let mut assignment: BTreeMap<u8, u8> = BTreeMap::new();
    let mut in_use: BTreeMap<u8, u8> = BTreeMap::new(); // phys -> virt
    let mut out = Vec::with_capacity(prog.len());
    for (pos, i) in prog.iter().enumerate() {
        // Expire.
        let expired: Vec<u8> = in_use
            .iter()
            .filter(|(_, v)| last.get(v).copied().unwrap_or(0) < pos)
            .map(|(p, _)| *p)
            .collect();
        for p in expired {
            in_use.remove(&p);
        }
        // Allocate any new virtuals in this instruction.
        for r in [i.rd, i.rs1, i.rs2, i.rs3] {
            if r >= 32 && !assignment.contains_key(&r) {
                let phys = POOL
                    .iter()
                    .find(|p| !in_use.contains_key(p))
                    .copied()
                    .ok_or_else(|| {
                        Error::Backend(format!(
                            "register pressure exceeds pool at instruction {pos} — spill required"
                        ))
                    })?;
                assignment.insert(r, phys);
                in_use.insert(phys, r);
            }
        }
        let map = |r: u8| if r >= 32 { assignment[&r] } else { r };
        out.push(Instr {
            op: i.op,
            rd: map(i.rd),
            rs1: map(i.rs1),
            rs2: map(i.rs2),
            rs3: map(i.rs3),
            imm: i.imm,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode_all;
    use crate::sim::machine::Machine;
    use crate::sim::MachineConfig;

    #[test]
    fn pressure_counts_distinct_registers() {
        let prog = vec![
            Instr::i(Op::Addi, 5, 0, 1),
            Instr::i(Op::Addi, 6, 5, 1),
            Instr::r(Op::Add, 7, 5, 6),
            Instr::r(Op::FaddS, 1, 2, 3),
        ];
        let p = analyze_pressure(&prog);
        assert_eq!(p.int_regs, 3);
        assert_eq!(p.float_regs, 3);
        assert_eq!(p.vector_regs, 0);
    }

    #[test]
    fn linear_scan_remaps_and_preserves_semantics() {
        // Virtual program: v32 = 3; v33 = 4; v34 = v32 + v33; store into x10.
        let prog = vec![
            Instr::i(Op::Addi, 32, 0, 3),
            Instr::i(Op::Addi, 33, 0, 4),
            Instr::r(Op::Add, 34, 32, 33),
            Instr::r(Op::Add, 10, 34, 0),
        ];
        let alloc = linear_scan(&prog).unwrap();
        assert!(alloc.iter().all(|i| i.rd < 32 && i.rs1 < 32 && i.rs2 < 32));
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.run(&encode_all(&alloc).unwrap()).unwrap();
        assert_eq!(m.x[10], 7);
    }

    #[test]
    fn linear_scan_reuses_dead_registers() {
        // 40 sequential short-lived virtuals must fit the 17-register pool.
        let mut prog = Vec::new();
        for v in 0..40u8 {
            let vr = 32 + (v % 60);
            prog.push(Instr::i(Op::Addi, vr, 0, v as i32));
            prog.push(Instr::r(Op::Add, 10, 10, vr)); // last use immediately
        }
        let alloc = linear_scan(&prog).unwrap();
        let p = analyze_pressure(&alloc);
        assert!(p.int_regs <= 18);
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.run(&encode_all(&alloc).unwrap()).unwrap();
        assert_eq!(m.x[10], (0..40).sum::<i32>());
    }

    #[test]
    fn over_pressure_rejected() {
        // 20 simultaneously-live virtuals > 17-register pool.
        let mut prog = Vec::new();
        for v in 0..20u8 {
            prog.push(Instr::i(Op::Addi, 32 + v, 0, v as i32));
        }
        // All still live here:
        for v in 0..20u8 {
            prog.push(Instr::r(Op::Add, 10, 10, 32 + v));
        }
        assert!(linear_scan(&prog).is_err());
    }

    #[test]
    fn kernel_pressure_within_files() {
        // Every generated kernel must fit the register files.
        use crate::codegen::kernels;
        use crate::codegen::KernelConfig;
        let mach = MachineConfig::xgen_asic();
        let art = kernels::matmul(&mach, KernelConfig::default(), 8, 8, 8, 0, 0x1000, 0x2000, crate::ir::DType::F32).unwrap();
        let p = analyze_pressure(&art.asm);
        assert!(p.int_regs <= 31, "{p:?}");
        assert!(p.float_regs <= 32);
        assert!(p.vector_regs <= 32);
    }
}
