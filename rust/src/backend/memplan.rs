//! Memory planner: DMEM (activations) and WMEM (weights) layout.
//!
//! * Activations get liveness-based **staggered allocation** (the paper's
//!   §4.5 "optimized memory layout (staggered allocation)"): a best-fit
//!   free-list keyed on last-use in topological order, so disjoint-lifetime
//!   tensors share addresses and DMEM peak stays near the live-set maximum.
//! * Pure view ops (Reshape/Flatten/Squeeze/Unsqueeze/Identity/Cast) alias
//!   their input — no allocation, no copy kernel.
//! * Weights are packed into WMEM with within-model content dedup (the
//!   cross-model consolidation of §5.1 lives in `pipeline::multi_model`).
//! * Composite kernels (Attention) receive per-node scratch regions.
//!
//! All addresses are 64-byte aligned (cache line), which `validate`
//! re-checks independently.

use std::collections::BTreeMap;

use crate::ir::dtype::DType;
use crate::ir::graph::{Graph, NodeId, TensorId};
use crate::ir::ops::OpKind;
use crate::sim::layout;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Alignment for every allocation (cache line).
pub const ALIGN: u32 = 64;

/// View ops that alias their input buffer. `DequantizeLinear` is *not* a
/// view: sub-byte compiles lower it to a real requantize kernel writing a
/// dequantized f32 buffer (aliasing it to the code buffer would hand raw
/// integer codes to the consumer kernel).
pub fn is_view_op(op: OpKind) -> bool {
    matches!(
        op,
        OpKind::Reshape
            | OpKind::Flatten
            | OpKind::Squeeze
            | OpKind::Unsqueeze
            | OpKind::Identity
            | OpKind::Cast
    )
}

/// Pack sub-byte integer codes into their deployed layout: I4 as
/// two's-complement nibbles (two per byte, low nibble first), Binary as sign
/// bits (LSB first; 1 = +1, 0 = -1). Functional simulation always stages
/// f32-wide — this layout feeds [`MemPlan::wmem_deployed`] accounting and
/// the precision-sweep artifact, never the emitted addresses.
pub fn pack_sub_byte(dt: DType, codes: &[f32]) -> Vec<u8> {
    match dt {
        DType::I4 => codes
            .chunks(2)
            .map(|c| {
                let lo = (c[0] as i32 & 0xF) as u8;
                let hi = (c.get(1).map(|&v| v as i32).unwrap_or(0) & 0xF) as u8;
                lo | (hi << 4)
            })
            .collect(),
        DType::Binary => {
            let mut out = vec![0u8; codes.len().div_ceil(8)];
            for (i, &v) in codes.iter().enumerate() {
                if v >= 0.0 {
                    out[i / 8] |= 1 << (i % 8);
                }
            }
            out
        }
        other => panic!("pack_sub_byte: {other} is not a sub-byte dtype"),
    }
}

/// Inverse of [`pack_sub_byte`]: recover `numel` codes from the packed
/// image (I4 nibbles sign-extend; Binary bits map to ±1).
pub fn unpack_sub_byte(dt: DType, bytes: &[u8], numel: usize) -> Vec<f32> {
    match dt {
        DType::I4 => (0..numel)
            .map(|i| {
                let b = bytes[i / 2];
                let nib = if i % 2 == 0 { b & 0xF } else { b >> 4 };
                (((nib as i8) << 4) >> 4) as f32
            })
            .collect(),
        DType::Binary => (0..numel)
            .map(|i| {
                if (bytes[i / 8] >> (i % 8)) & 1 == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect(),
        other => panic!("unpack_sub_byte: {other} is not a sub-byte dtype"),
    }
}

/// One placed buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub addr: u32,
    pub bytes: u32,
}

/// The plan: addresses for every tensor plus per-node scratch.
#[derive(Debug, Clone, Default)]
pub struct MemPlan {
    /// Activation placements (DMEM address space).
    pub dmem: BTreeMap<TensorId, Placement>,
    /// Weight placements (WMEM address space).
    pub wmem: BTreeMap<TensorId, Placement>,
    /// Scratch region per node (DMEM).
    pub scratch: BTreeMap<NodeId, Placement>,
    /// Peak DMEM usage in bytes (under the node order the plan was built
    /// with).
    pub dmem_peak: u32,
    /// Peak DMEM usage the *original* (unscheduled) node order would have
    /// needed. [`plan`] initializes it to `dmem_peak`; the compile pipeline
    /// overwrites it with the pre-reorder baseline when the memory-aware
    /// scheduler changed the order, so `dmem_peak <= dmem_peak_unscheduled`
    /// always holds (the scheduler keeps whichever order is lower).
    pub dmem_peak_unscheduled: u32,
    /// Total WMEM bytes (after within-model dedup) at f32-wide staging —
    /// the functional-simulation layout every emitted address strides by.
    pub wmem_used: u32,
    /// WMEM bytes before dedup (for the consolidation report).
    pub wmem_raw: u32,
    /// Deployed weight bytes after dedup, at the *storage* dtype: sub-byte
    /// weights count their nibble/bit-packed image (`pack_sub_byte`), wider
    /// dtypes their natural width. This is the Table 2 "bytes" column.
    pub wmem_deployed: u32,
}

impl MemPlan {
    /// Absolute address of a tensor (input, activation, or weight).
    pub fn addr_of(&self, t: TensorId) -> Result<u32> {
        if let Some(p) = self.dmem.get(&t) {
            return Ok(layout::DMEM_BASE + p.addr);
        }
        if let Some(p) = self.wmem.get(&t) {
            return Ok(layout::WMEM_BASE + p.addr);
        }
        Err(Error::Backend(format!("tensor {} not placed", t.0)))
    }

    pub fn scratch_of(&self, n: NodeId) -> Option<u32> {
        self.scratch.get(&n).map(|p| layout::DMEM_BASE + p.addr)
    }

    /// Export the plan's calling convention as a symbol table (see
    /// [`ModelAbi`]).
    pub fn abi(&self, g: &Graph) -> Result<ModelAbi> {
        ModelAbi::build(g, self)
    }
}

/// Role of a symbol in the compiled model's calling convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SymKind {
    Input,
    Output,
    Weight,
}

impl SymKind {
    pub fn name(self) -> &'static str {
        match self {
            SymKind::Input => "input",
            SymKind::Output => "output",
            SymKind::Weight => "weight",
        }
    }
}

/// One named, addressed buffer of the compiled model's interface.
#[derive(Debug, Clone)]
pub struct AbiSymbol {
    pub name: String,
    pub tensor: TensorId,
    pub kind: SymKind,
    /// Absolute address (DMEM or WMEM space, base included).
    pub addr: u32,
    /// Staged extent in bytes (f32 functional-simulation storage).
    pub bytes: u32,
    /// Worst-case extents (equal to the static shape for static graphs).
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl AbiSymbol {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// The artifact's symbol table: everything a runtime needs to stage inputs
/// and weights into DMEM/WMEM and read outputs back, without the graph or
/// plan in hand. Exported by codegen into [`crate::codegen::graphgen::Program`]
/// and consumed by `runtime::simrun`.
#[derive(Debug, Clone, Default)]
pub struct ModelAbi {
    pub symbols: Vec<AbiSymbol>,
}

impl ModelAbi {
    /// Build the symbol table: graph inputs, then outputs, then weights.
    pub fn build(g: &Graph, plan: &MemPlan) -> Result<ModelAbi> {
        let mut symbols = Vec::new();
        let mut push = |t: TensorId, kind: SymKind| -> Result<()> {
            let info = &g.tensors[t.0];
            let dims: Vec<usize> = match &info.shape {
                Some(s) => s.0.iter().map(|d| d.upper_bound()).collect(),
                None => {
                    return Err(Error::Backend(format!(
                        "abi: tensor '{}' has no inferred shape",
                        info.name
                    )))
                }
            };
            let (placement, base) = match (plan.dmem.get(&t), plan.wmem.get(&t)) {
                (Some(p), _) => (*p, layout::DMEM_BASE),
                (None, Some(p)) => (*p, layout::WMEM_BASE),
                (None, None) => {
                    return Err(Error::Backend(format!(
                        "abi: tensor '{}' not placed",
                        info.name
                    )))
                }
            };
            symbols.push(AbiSymbol {
                name: info.name.clone(),
                tensor: t,
                kind,
                addr: base + placement.addr,
                bytes: placement.bytes,
                dims,
                dtype: info.dtype,
            });
            Ok(())
        };
        for t in &g.inputs {
            push(*t, SymKind::Input)?;
        }
        for t in &g.outputs {
            push(*t, SymKind::Output)?;
        }
        for t in g.initializers.keys() {
            push(*t, SymKind::Weight)?;
        }
        Ok(ModelAbi { symbols })
    }

    pub fn inputs(&self) -> impl Iterator<Item = &AbiSymbol> {
        self.symbols.iter().filter(|s| s.kind == SymKind::Input)
    }

    pub fn outputs(&self) -> impl Iterator<Item = &AbiSymbol> {
        self.symbols.iter().filter(|s| s.kind == SymKind::Output)
    }

    pub fn weights(&self) -> impl Iterator<Item = &AbiSymbol> {
        self.symbols.iter().filter(|s| s.kind == SymKind::Weight)
    }

    pub fn find(&self, name: &str) -> Option<&AbiSymbol> {
        self.symbols.iter().find(|s| s.name == name)
    }

    /// JSON rendering (written next to `.s`/`.hex` by `xgenc compile --out`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "symbols",
            Json::Arr(
                self.symbols
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str_(&s.name)),
                            ("kind", Json::str_(s.kind.name())),
                            ("addr", Json::Num(s.addr as f64)),
                            ("bytes", Json::Num(s.bytes as f64)),
                            (
                                "dims",
                                Json::num_arr(
                                    &s.dims.iter().map(|&d| d as f64).collect::<Vec<f64>>(),
                                ),
                            ),
                            ("dtype", Json::str_(s.dtype.name())),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

fn align(x: u32) -> u32 {
    x.div_ceil(ALIGN) * ALIGN
}

/// Bytes a tensor occupies in DMEM (activations are stored at f32 width in
/// the functional simulator; quantized storage width affects WMEM and the
/// PPA model, not the simulation layout). Also the size model the
/// memory-aware node scheduler ([`super::sched::memory_aware_order`]) scores
/// candidate orders with.
pub(crate) fn act_bytes(g: &Graph, t: TensorId) -> Result<u32> {
    let shape = g.shape_of(t)?;
    Ok(align((shape.numel_upper() * 4) as u32).max(ALIGN))
}

/// Scratch bytes needed by a node's kernel (beyond inputs/outputs).
fn scratch_bytes(g: &Graph, node_idx: usize) -> Result<u32> {
    let node = &g.nodes[node_idx];
    Ok(match node.op {
        OpKind::Attention => {
            // q, k, v projections [B*S, D] x3 + scores [S, S].
            let x = g.shape_of(node.inputs[0])?;
            let dims = x.dims();
            let (b, s, d) = (dims[0], dims[1], dims[2]);
            align((3 * b * s * d * 4 + s * s * 4) as u32)
        }
        _ => 0,
    })
}

/// Free-list allocator with best-fit reuse.
#[derive(Default)]
struct FreeList {
    /// (addr, bytes) free blocks, sorted by addr.
    free: Vec<(u32, u32)>,
    top: u32,
    peak: u32,
}

impl FreeList {
    fn alloc(&mut self, bytes: u32) -> u32 {
        // Best fit.
        let mut best: Option<usize> = None;
        for (i, (_, sz)) in self.free.iter().enumerate() {
            if *sz >= bytes && best.map(|b| self.free[b].1 > *sz).unwrap_or(true) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            let (addr, sz) = self.free[i];
            if sz == bytes {
                self.free.remove(i);
            } else {
                self.free[i] = (addr + bytes, sz - bytes);
            }
            return addr;
        }
        let addr = self.top;
        self.top += bytes;
        self.peak = self.peak.max(self.top);
        addr
    }

    fn release(&mut self, addr: u32, bytes: u32) {
        // Insert and coalesce neighbours.
        let pos = self.free.partition_point(|(a, _)| *a < addr);
        self.free.insert(pos, (addr, bytes));
        // Coalesce right then left.
        if pos + 1 < self.free.len() {
            let (a2, s2) = self.free[pos + 1];
            if addr + bytes == a2 {
                self.free[pos].1 += s2;
                self.free.remove(pos + 1);
            }
        }
        if pos > 0 {
            let (a0, s0) = self.free[pos - 1];
            if a0 + s0 == addr {
                self.free[pos - 1].1 += self.free[pos].1;
                self.free.remove(pos);
            }
        }
    }
}

/// Build the full memory plan for a graph.
pub fn plan(g: &Graph, dmem_capacity: u32, wmem_capacity: u32) -> Result<MemPlan> {
    let order = g.topo_order()?;
    let mut plan = MemPlan::default();

    // -- WMEM: pack weights with content dedup -----------------------------
    let mut by_hash: BTreeMap<u64, Placement> = BTreeMap::new();
    let mut wtop: u32 = 0;
    for (tid, init) in &g.initializers {
        // Like `act_bytes`: the functional simulator stores every value at
        // f32 width, and generated kernels stride weights at 4 bytes per
        // element — quantized *deployed* width is accounted in
        // `wmem_deployed`/`QuantPlan` and the PPA model, never in the
        // simulation layout. (Placing quantized weights at their narrow
        // width would make the emitted addresses overlap at runtime.)
        let bytes = align(((init.numel() * 4).max(1)) as u32);
        plan.wmem_raw += bytes;
        let h = init.content_hash();
        let placement = match by_hash.get(&h) {
            Some(p) => *p,
            None => {
                let p = Placement { addr: wtop, bytes };
                wtop += bytes;
                by_hash.insert(h, p);
                // Deployed footprint counts each distinct buffer once, at
                // its storage width: ceil(numel * bits / 8). For sub-byte
                // codes this equals `pack_sub_byte(..).len()` exactly
                // (`pack_length_matches_deployed_accounting` pins it), so
                // the planner never materializes weights just to size them.
                plan.wmem_deployed +=
                    ((init.numel() as u64 * init.dtype.bits() as u64).div_ceil(8)) as u32;
                p
            }
        };
        plan.wmem.insert(*tid, placement);
    }
    plan.wmem_used = wtop;
    if wtop > wmem_capacity {
        return Err(Error::Backend(format!(
            "WMEM overflow: need {} bytes, capacity {}",
            wtop, wmem_capacity
        )));
    }

    // -- DMEM: liveness + staggered reuse -----------------------------------
    // last_use[tensor] = index in `order` of its final consumer.
    let mut last_use: BTreeMap<TensorId, usize> = BTreeMap::new();
    for (pos, nid) in order.iter().enumerate() {
        for t in &g.nodes[nid.0].inputs {
            last_use.insert(*t, pos);
        }
    }
    // Graph outputs and inputs live forever.
    for t in g.outputs.iter().chain(&g.inputs) {
        last_use.insert(*t, usize::MAX);
    }

    // Resolve view-op aliases to their root buffer.
    let mut alias_root: BTreeMap<TensorId, TensorId> = BTreeMap::new();
    let root_of = |alias_root: &BTreeMap<TensorId, TensorId>, mut t: TensorId| {
        while let Some(r) = alias_root.get(&t) {
            t = *r;
        }
        t
    };
    // Extend root lifetimes through their aliases.
    for nid in &order {
        let node = &g.nodes[nid.0];
        if is_view_op(node.op) && !node.inputs.is_empty() {
            alias_root.insert(node.outputs[0], node.inputs[0]);
        }
    }
    let mut root_last_use: BTreeMap<TensorId, usize> = BTreeMap::new();
    for (t, pos) in &last_use {
        let r = root_of(&alias_root, *t);
        let e = root_last_use.entry(r).or_insert(0);
        *e = (*e).max(*pos);
    }

    let mut fl = FreeList::default();
    // Graph inputs first.
    for t in &g.inputs {
        let bytes = act_bytes(g, *t)?;
        let addr = fl.alloc(bytes);
        plan.dmem.insert(*t, Placement { addr, bytes });
    }
    // Walk nodes: allocate outputs + scratch, release dead tensors.
    // expirations[pos] = roots whose last use is pos.
    for (pos, nid) in order.iter().enumerate() {
        let node = &g.nodes[nid.0];
        if is_view_op(node.op) && !node.inputs.is_empty() {
            // Alias: same placement as the (root) input.
            let r = root_of(&alias_root, node.outputs[0]);
            if let Some(p) = plan.dmem.get(&r).copied() {
                plan.dmem.insert(node.outputs[0], p);
            } else if let Some(p) = plan.wmem.get(&r).copied() {
                plan.wmem.insert(node.outputs[0], p);
            }
        } else {
            for t in &node.outputs {
                let bytes = act_bytes(g, *t)?;
                let addr = fl.alloc(bytes);
                plan.dmem.insert(*t, Placement { addr, bytes });
            }
        }
        let sb = scratch_bytes(g, nid.0)?;
        if sb > 0 {
            // Scratch is released immediately after the node.
            let addr = fl.alloc(sb);
            plan.scratch.insert(*nid, Placement { addr, bytes: sb });
            fl.release(addr, sb);
        }
        // Release buffers whose root lifetime ends here.
        for (t, p) in plan.dmem.clone() {
            if alias_root.contains_key(&t) {
                continue; // aliases don't own storage
            }
            if root_last_use.get(&t).copied().unwrap_or(0) == pos && !g.inputs.contains(&t) {
                fl.release(p.addr, p.bytes);
                // Keep the placement record (addresses remain valid in the
                // generated code; the block is just reusable now).
            }
        }
    }
    plan.dmem_peak = fl.peak;
    plan.dmem_peak_unscheduled = fl.peak;
    if plan.dmem_peak > dmem_capacity {
        return Err(Error::Backend(format!(
            "DMEM overflow: peak {} bytes, capacity {} — reduce batch or quantize activations",
            plan.dmem_peak, dmem_capacity
        )));
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{model_zoo, prepare};
    use crate::ir::dtype::DType;
    use crate::ir::ops::Attrs;
    use crate::ir::shape::Shape;
    use crate::ir::tensor::Initializer;
    use crate::util::proptest::forall;

    fn planned(g: &Graph) -> MemPlan {
        plan(g, 1 << 30, 2 << 30).unwrap()
    }

    #[test]
    fn chain_reuses_memory() {
        // x -> relu -> relu -> ... long chain: peak should be ~2 buffers,
        // not N.
        let mut g = Graph::new("chain");
        let mut x = g.input("x", Shape::fixed(&[1, 1024]), DType::F32);
        for i in 0..20 {
            x = g.node(OpKind::Relu, &format!("r{i}"), &[x], Attrs::new());
        }
        g.outputs.push(x);
        let g = prepare(g).unwrap();
        let p = planned(&g);
        let one = act_bytes(&g, g.inputs[0]).unwrap();
        assert!(
            p.dmem_peak <= 3 * one,
            "peak {} vs buffer {}",
            p.dmem_peak,
            one
        );
    }

    #[test]
    fn view_ops_alias() {
        let mut g = Graph::new("v");
        let x = g.input("x", Shape::fixed(&[2, 8]), DType::F32);
        let mut attrs = Attrs::new();
        attrs.insert("shape".into(), crate::ir::ops::AttrValue::Ints(vec![16]));
        let y = g.node(OpKind::Reshape, "rs", &[x], attrs);
        let z = g.node(OpKind::Relu, "r", &[y], Attrs::new());
        g.outputs.push(z);
        let g = prepare(g).unwrap();
        let p = planned(&g);
        assert_eq!(p.dmem[&x], p.dmem[&y], "reshape must alias its input");
        assert_ne!(p.dmem[&x], p.dmem[&z]);
    }

    #[test]
    fn wmem_dedups_identical_content() {
        let mut g = Graph::new("d");
        let x = g.input("x", Shape::fixed(&[1, 8]), DType::F32);
        let w1 = g.init(Initializer::lazy("w1", &[8, 8], 7, 0.1));
        let w2 = g.init(Initializer::lazy("w2", &[8, 8], 7, 0.1)); // same recipe
        let w3 = g.init(Initializer::lazy("w3", &[8, 8], 8, 0.1)); // different
        let a = g.node(OpKind::MatMul, "m1", &[x, w1], Attrs::new());
        let b = g.node(OpKind::MatMul, "m2", &[a, w2], Attrs::new());
        let c = g.node(OpKind::MatMul, "m3", &[b, w3], Attrs::new());
        g.outputs.push(c);
        let g = prepare(g).unwrap();
        let p = planned(&g);
        assert_eq!(p.wmem[&w1], p.wmem[&w2]);
        assert_ne!(p.wmem[&w1], p.wmem[&w3]);
        assert!(p.wmem_used < p.wmem_raw);
    }

    #[test]
    fn alignment_everywhere() {
        let g = prepare(model_zoo::resnet_cifar(1)).unwrap();
        let p = planned(&g);
        for pl in p.dmem.values().chain(p.wmem.values()) {
            assert_eq!(pl.addr % ALIGN, 0);
        }
    }

    #[test]
    fn capacity_enforced() {
        let g = prepare(model_zoo::mlp(&[4096, 4096, 4096], 8)).unwrap();
        assert!(plan(&g, 1 << 10, 2 << 30).is_err(), "tiny DMEM must fail");
        assert!(plan(&g, 1 << 30, 1 << 10).is_err(), "tiny WMEM must fail");
    }

    #[test]
    fn attention_gets_scratch() {
        let g = prepare(model_zoo::bert_tiny(1, 32)).unwrap();
        let p = planned(&g);
        let n_attn = g.nodes.iter().filter(|n| n.op == OpKind::Attention).count();
        assert_eq!(p.scratch.len(), n_attn);
        for (nid, pl) in &p.scratch {
            let node = &g.nodes[nid.0];
            assert_eq!(node.op, OpKind::Attention);
            assert!(pl.bytes >= 32 * 32 * 4);
        }
    }

    #[test]
    fn abi_symbols_cover_io_and_weights() {
        let g = prepare(model_zoo::mlp(&[16, 8, 4], 2)).unwrap();
        let p = planned(&g);
        let abi = p.abi(&g).unwrap();
        assert_eq!(abi.inputs().count(), g.inputs.len());
        assert_eq!(abi.outputs().count(), g.outputs.len());
        assert_eq!(abi.weights().count(), g.initializers.len());
        let x = abi.find("x").unwrap();
        assert_eq!(x.kind, SymKind::Input);
        assert_eq!(x.dims, vec![2, 16]);
        assert_eq!(x.addr, p.addr_of(g.inputs[0]).unwrap());
        assert!(x.bytes >= (x.numel() * 4) as u32);
        for w in abi.weights() {
            assert!(w.addr >= crate::sim::layout::WMEM_BASE, "{}", w.name);
        }
        let text = abi.to_json().to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn quantized_weights_keep_f32_simulation_extents() {
        // The functional machine stores f32 and kernels stride weights at 4
        // bytes/element, so quantized compiles must not shrink placements.
        let mut g = prepare(model_zoo::mlp(&[32, 16, 8], 1)).unwrap();
        crate::quant::ptq::quantize_graph(
            &mut g,
            DType::I8,
            crate::quant::calib::Method::MinMax,
            &[],
        )
        .unwrap();
        let p = planned(&g);
        for (tid, init) in &g.initializers {
            assert!(
                p.wmem[tid].bytes >= (init.numel() * 4) as u32,
                "{} placed at quantized width",
                init.name
            );
        }
    }

    #[test]
    fn sub_byte_pack_covers_all_values() {
        // Exhaustive: every I4 code and both Binary codes round-trip.
        let all: Vec<f32> = (-8..=7).map(|v| v as f32).collect();
        let packed = pack_sub_byte(DType::I4, &all);
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_sub_byte(DType::I4, &packed, 16), all);
        let b = vec![1.0, -1.0, -1.0, 1.0, 1.0, -1.0, 1.0, 1.0, -1.0];
        let pb = pack_sub_byte(DType::Binary, &b);
        assert_eq!(pb.len(), 2);
        assert_eq!(unpack_sub_byte(DType::Binary, &pb, 9), b);
    }

    #[test]
    fn pack_length_matches_deployed_accounting() {
        // The planner sizes deployed sub-byte buffers arithmetically
        // (ceil(numel * bits / 8)) instead of materializing + packing;
        // this pins that the formula and the real packed image agree.
        for n in [1usize, 2, 7, 8, 9, 15, 16, 17, 100] {
            let i4 = vec![-8.0f32; n];
            assert_eq!(
                pack_sub_byte(DType::I4, &i4).len() as u64,
                (n as u64 * DType::I4.bits() as u64).div_ceil(8)
            );
            let bin = vec![1.0f32; n];
            assert_eq!(
                pack_sub_byte(DType::Binary, &bin).len() as u64,
                (n as u64 * DType::Binary.bits() as u64).div_ceil(8)
            );
        }
    }

    #[test]
    fn property_sub_byte_pack_roundtrip() {
        // pack -> unpack is the identity for random code vectors of odd and
        // even lengths (tail nibbles/bits included).
        forall("sub-byte pack/unpack identity", 60, |rng| {
            let n = rng.range(1, 40) as usize;
            let i4: Vec<f32> = (0..n).map(|_| rng.range(-8, 8) as f32).collect();
            let got = unpack_sub_byte(DType::I4, &pack_sub_byte(DType::I4, &i4), n);
            if got != i4 {
                return Err(format!("I4 n={n}: {got:?} != {i4:?}"));
            }
            let bin: Vec<f32> =
                (0..n).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect();
            let got = unpack_sub_byte(DType::Binary, &pack_sub_byte(DType::Binary, &bin), n);
            if got != bin {
                return Err(format!("Binary n={n}: {got:?} != {bin:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn deployed_bytes_shrink_with_precision_but_staging_stays_f32() {
        let g0 = prepare(model_zoo::mlp(&[32, 16, 8], 1)).unwrap();
        let p0 = planned(&g0);
        assert_eq!(p0.wmem_deployed, g0.param_count() as u32 * 4);
        let mut prev = u64::MAX;
        for dt in [DType::I8, DType::I4, DType::Binary] {
            let mut gq = g0.clone();
            crate::quant::ptq::quantize_graph(
                &mut gq,
                dt,
                crate::quant::calib::Method::MinMax,
                &[],
            )
            .unwrap();
            let p = planned(&gq);
            // Staging (emitted addresses) stays f32-wide at every precision.
            assert_eq!(p.wmem_used, p0.wmem_used, "{dt}");
            assert!(
                (p.wmem_deployed as u64) < prev && p.wmem_deployed < p0.wmem_deployed,
                "{dt}: deployed {} not shrinking",
                p.wmem_deployed
            );
            prev = p.wmem_deployed as u64;
        }
    }

    #[test]
    fn dequantize_is_a_real_buffer_not_a_view() {
        // Sub-byte dequant outputs must get their own DMEM allocation:
        // aliasing them onto the WMEM code buffer would feed raw integer
        // codes to the consumer kernels.
        assert!(!is_view_op(OpKind::DequantizeLinear));
        let mut g = prepare(model_zoo::mlp(&[16, 8, 4], 1)).unwrap();
        crate::quant::ptq::quantize_graph(
            &mut g,
            DType::I4,
            crate::quant::calib::Method::MinMax,
            &[],
        )
        .unwrap();
        let p = planned(&g);
        for node in g.nodes.iter().filter(|n| n.op == OpKind::DequantizeLinear) {
            let out = node.outputs[0];
            assert!(p.dmem.contains_key(&out), "'{}' output not in DMEM", node.name);
            assert!(p.wmem.contains_key(&node.inputs[0]), "'{}' codes not in WMEM", node.name);
        }
    }

    #[test]
    fn property_live_buffers_never_overlap() {
        // For random chains/diamonds: at every program point, placements of
        // simultaneously-live (root) tensors must not overlap.
        forall("no live overlap", 30, |rng| {
            let mut g = Graph::new("p");
            let mut live: Vec<TensorId> = vec![g.input("x", Shape::fixed(&[1, 64]), DType::F32)];
            for i in 0..12 {
                let a = *rng.choose(&live);
                let op = [OpKind::Relu, OpKind::Sigmoid, OpKind::Add][rng.index(3)];
                let t = if op == OpKind::Add {
                    let b = *rng.choose(&live);
                    g.node(OpKind::Add, &format!("n{i}"), &[a, b], Attrs::new())
                } else {
                    g.node(op, &format!("n{i}"), &[a], Attrs::new())
                };
                live.push(t);
            }
            let out = *live.last().unwrap();
            g.outputs.push(out);
            let g = prepare(g).map_err(|e| format!("{e}"))?;
            let p = plan(&g, 1 << 30, 1 << 30).map_err(|e| format!("{e}"))?;
            // Reconstruct liveness and check overlap at each step.
            let order = g.topo_order().unwrap();
            let mut last_use: BTreeMap<TensorId, usize> = BTreeMap::new();
            for (pos, nid) in order.iter().enumerate() {
                for t in &g.nodes[nid.0].inputs {
                    last_use.insert(*t, pos);
                }
            }
            for t in g.outputs.iter().chain(&g.inputs) {
                last_use.insert(*t, usize::MAX);
            }
            for (pos, nid) in order.iter().enumerate() {
                // live set: defined at or before pos, last use at or after pos
                let mut live_now: Vec<TensorId> = Vec::new();
                for t in g.inputs.iter().copied() {
                    if last_use.get(&t).copied().unwrap_or(0) >= pos {
                        live_now.push(t);
                    }
                }
                for (dpos, dnid) in order.iter().enumerate() {
                    if dpos > pos {
                        break;
                    }
                    for t in &g.nodes[dnid.0].outputs {
                        if last_use.get(t).copied().unwrap_or(0) >= pos {
                            live_now.push(*t);
                        }
                    }
                }
                for (i, &a) in live_now.iter().enumerate() {
                    for &b in &live_now[i + 1..] {
                        let (pa, pb) = (p.dmem[&a], p.dmem[&b]);
                        let overlap =
                            pa.addr < pb.addr + pb.bytes && pb.addr < pa.addr + pa.bytes;
                        if overlap && pa != pb {
                            return Err(format!(
                                "node {}: tensors {} and {} overlap: {pa:?} {pb:?}",
                                nid.0, a.0, b.0
                            ));
                        }
                    }
                }
                let _ = pos;
            }
            Ok(())
        });
    }
}
