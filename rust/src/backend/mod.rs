//! Backend (paper §3.1 stage 4): memory planning, register allocation,
//! instruction scheduling, and HEX emission.

pub mod hex;
pub mod memplan;
pub mod regalloc;
pub mod sched;
