//! Schedulers at two levels.
//!
//! **Graph level** ([`memory_aware_order`]): liveness-aware topological node
//! ordering that greedily minimizes peak live DMEM. Invariants: the result
//! is always a valid topological order of the data dependences; graph inputs
//! and outputs are pinned live for the whole program (a buffer is considered
//! freed only once its *last* internal consumer has run and it is not a graph
//! output); the compile pipeline only adopts the order when the memory
//! planner's measured peak is no worse than the original order's, so
//! `MemPlan::dmem_peak <= MemPlan::dmem_peak_unscheduled` always holds.
//!
//! **Instruction level** ([`schedule`]): list scheduling within basic blocks
//! to separate producers from consumers (the paper's "efficient instruction
//! scheduling (reduced pipeline stalls)", §4.4). Conservative dependence
//! model: register RAW/WAR/WAW, all memory ops ordered among themselves,
//! vector state (`vsetvli`) is a barrier, control flow ends a block.
//! Correctness is re-checked by running scheduled kernels on the functional
//! machine.

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::memplan;
use crate::ir::graph::{Graph, NodeId, TensorId};
use crate::isa::encode::{format_of, Format};
use crate::isa::{Instr, Op, OpClass};
use crate::util::error::{Error, Result};

/// Liveness-aware topological order over the graph's nodes, chosen to keep
/// the peak number of live DMEM bytes low: among ready nodes, greedily pick
/// the one with the smallest `allocated - freed` byte delta (ties broken by
/// original node index, so the order is deterministic and degenerates to the
/// original order on chains).
///
/// A node *frees* an input buffer when it is that buffer's last remaining
/// consumer and the buffer is not a graph input/output (those stay live for
/// the whole program — the output-aware liveness rule the fusion passes also
/// observe). View-op outputs alias their input and allocate nothing.
///
/// This is a scoring heuristic: the authoritative peak is whatever
/// [`memplan::plan`] measures for the resulting order, and the compile
/// pipeline keeps the original order whenever it measures no worse.
pub fn memory_aware_order(g: &Graph) -> Result<Vec<NodeId>> {
    let n = g.nodes.len();
    let mut producer: BTreeMap<TensorId, usize> = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        for t in &node.outputs {
            producer.insert(*t, i);
        }
    }
    // Node dependence edges via tensor producers.
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in g.nodes.iter().enumerate() {
        let mut preds: BTreeSet<usize> = BTreeSet::new();
        for t in &node.inputs {
            if let Some(&p) = producer.get(t) {
                if p != i {
                    preds.insert(p);
                }
            }
        }
        indeg[i] = preds.len();
        for p in preds {
            succs[p].push(i);
        }
    }
    // Remaining internal consumers per tensor; graph inputs/outputs pinned.
    let mut uses: BTreeMap<TensorId, usize> = BTreeMap::new();
    for node in &g.nodes {
        for t in &node.inputs {
            *uses.entry(*t).or_insert(0) += 1;
        }
    }
    let pinned: BTreeSet<TensorId> = g.inputs.iter().chain(&g.outputs).copied().collect();
    let bytes = |t: TensorId| -> i64 { memplan::act_bytes(g, t).unwrap_or(memplan::ALIGN) as i64 };

    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order: Vec<NodeId> = Vec::with_capacity(n);
    while !ready.is_empty() {
        // Score each ready node: DMEM delta if run next.
        let mut best: Option<(i64, usize)> = None;
        for &i in &ready {
            let node = &g.nodes[i];
            let alloc: i64 = if memplan::is_view_op(node.op) {
                0
            } else {
                node.outputs.iter().map(|&t| bytes(t)).sum()
            };
            let mut freed: i64 = 0;
            let mut seen: BTreeSet<TensorId> = BTreeSet::new();
            for &t in &node.inputs {
                if !seen.insert(t) {
                    continue;
                }
                let mine = node.inputs.iter().filter(|&&x| x == t).count();
                if uses.get(&t).copied().unwrap_or(0) == mine && !pinned.contains(&t) {
                    freed += bytes(t);
                }
            }
            let key = (alloc - freed, i);
            if best.map(|b| key < b).unwrap_or(true) {
                best = Some(key);
            }
        }
        let (_, pick) = best.expect("ready set non-empty");
        ready.retain(|&i| i != pick);
        order.push(NodeId(pick));
        for &t in &g.nodes[pick].inputs {
            if let Some(u) = uses.get_mut(&t) {
                *u = u.saturating_sub(1);
            }
        }
        for &s in &succs[pick] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(s);
            }
        }
    }
    if order.len() != n {
        return Err(Error::Backend("memory_aware_order: graph has a cycle".into()));
    }
    Ok(order)
}

/// Physically permute `g.nodes` into `order` (which must be a permutation of
/// all node ids). Kahn-style `topo_order` scans in index order, so after this
/// every downstream consumer (planner, tuner, codegen) adopts the schedule.
pub fn apply_node_order(g: &mut Graph, order: &[NodeId]) {
    debug_assert_eq!(order.len(), g.nodes.len());
    let nodes = std::mem::take(&mut g.nodes);
    let mut slots: Vec<Option<crate::ir::graph::Node>> = nodes.into_iter().map(Some).collect();
    g.nodes = order
        .iter()
        .map(|nid| slots[nid.0].take().expect("order must be a permutation"))
        .collect();
}

/// Result latency (cycles until the destination is ready).
fn latency(op: Op) -> u64 {
    match op.class() {
        OpClass::Mul => 3,
        OpClass::Div => 20,
        OpClass::Load => 3,
        OpClass::FAlu => 2,
        OpClass::FMul => 3,
        OpClass::FDiv => 16,
        OpClass::FMa => 4,
        OpClass::FCustom => 8,
        OpClass::VLoad => 4,
        OpClass::VFma | OpClass::VMul => 3,
        _ => 1,
    }
}

/// Register sets (file, id) read/written by an instruction.
/// File tag: 0 = int, 1 = float, 2 = vector.
fn reads_writes(i: &Instr) -> (Vec<(u8, u8)>, Vec<(u8, u8)>) {
    let mut r = Vec::new();
    let mut w = Vec::new();
    match format_of(i.op) {
        Format::R => {
            let float = matches!(
                i.op.class(),
                OpClass::FAlu | OpClass::FMul | OpClass::FDiv | OpClass::FCustom
            );
            match i.op {
                Op::FcvtWS => {
                    r.push((1, i.rs1));
                    w.push((0, i.rd));
                }
                Op::FcvtSW => {
                    r.push((0, i.rs1));
                    w.push((1, i.rd));
                }
                _ if float => {
                    r.push((1, i.rs1));
                    r.push((1, i.rs2));
                    w.push((1, i.rd));
                }
                _ => {
                    r.push((0, i.rs1));
                    r.push((0, i.rs2));
                    w.push((0, i.rd));
                }
            }
        }
        Format::R4 => {
            r.push((1, i.rs1));
            r.push((1, i.rs2));
            r.push((1, i.rs3));
            w.push((1, i.rd));
        }
        Format::I => {
            r.push((0, i.rs1));
            if i.op == Op::Flw {
                w.push((1, i.rd));
            } else {
                w.push((0, i.rd));
            }
        }
        Format::S => {
            r.push((0, i.rs1));
            r.push((if i.op == Op::Fsw { 1 } else { 0 }, i.rs2));
        }
        Format::B => {
            r.push((0, i.rs1));
            r.push((0, i.rs2));
        }
        Format::U | Format::J => w.push((0, i.rd)),
        Format::VSetF => {
            r.push((0, i.rs1));
            w.push((0, i.rd));
        }
        Format::VMem => {
            r.push((0, i.rs1));
            if matches!(i.op, Op::Vle32 | Op::Vle8) {
                w.push((2, i.rd));
            } else {
                r.push((2, i.rd));
            }
        }
        Format::VArith => {
            match i.op {
                Op::VfmaccVF | Op::VfmvVF => r.push((1, i.rs1)),
                _ => r.push((2, i.rs1)),
            }
            r.push((2, i.rs2));
            if matches!(i.op, Op::VmaccVV | Op::VfmaccVV | Op::VfmaccVF) {
                r.push((2, i.rd)); // accumulator also read
            }
            w.push((2, i.rd));
        }
    }
    // x0 writes are no-ops.
    w.retain(|(f, id)| !(*f == 0 && *id == 0));
    (r, w)
}

fn is_mem(op: Op) -> bool {
    matches!(
        op.class(),
        OpClass::Load | OpClass::Store | OpClass::VLoad | OpClass::VStore
    )
}

fn is_barrier(op: Op) -> bool {
    matches!(
        op.class(),
        OpClass::Branch | OpClass::Jump | OpClass::VSet
    )
}

/// Schedule one basic block: topological order by dependences, prioritizing
/// the critical path (longest latency-weighted chain to any sink).
fn schedule_block(block: &[Instr]) -> Vec<Instr> {
    let n = block.len();
    if n <= 2 {
        return block.to_vec();
    }
    // Build dependence edges.
    let mut deps: Vec<Vec<usize>> = vec![Vec::new(); n]; // deps[i] = predecessors
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        let (ri, wi) = reads_writes(&block[i]);
        for j in 0..i {
            let (rj, wj) = reads_writes(&block[j]);
            let raw = wj.iter().any(|x| ri.contains(x));
            let war = rj.iter().any(|x| wi.contains(x));
            let waw = wj.iter().any(|x| wi.contains(x));
            let mem = is_mem(block[i].op) && is_mem(block[j].op);
            if raw || war || waw || mem {
                deps[i].push(j);
                succs[j].push(i);
            }
        }
    }
    // Critical-path priority.
    let mut prio = vec![0u64; n];
    for i in (0..n).rev() {
        let succ_max = succs[i].iter().map(|&s| prio[s]).max().unwrap_or(0);
        prio[i] = latency(block[i].op) + succ_max;
    }
    // List schedule.
    let mut indeg: Vec<usize> = deps.iter().map(|d| d.len()).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut out = Vec::with_capacity(n);
    let mut emitted = vec![false; n];
    while out.len() < n {
        // Pick the ready instruction with the highest priority; stable on
        // original order for determinism.
        ready.sort_by_key(|&i| (std::cmp::Reverse(prio[i]), i));
        let pick = ready.remove(0);
        emitted[pick] = true;
        out.push(block[pick]);
        for &s in &succs[pick] {
            indeg[s] -= 1;
            if indeg[s] == 0 && !emitted[s] {
                ready.push(s);
            }
        }
    }
    out
}

/// Schedule a whole program. Block boundaries: any branch/jump/vsetvli ends
/// a block (inclusive), and any *branch target* starts one. Since labels are
/// resolved to offsets already, we conservatively only reorder *between*
/// consecutive control instructions, which is safe for targets too (targets
/// always follow a branch in our kernels' structured loops).
pub fn schedule(prog: &[Instr]) -> Vec<Instr> {
    let mut out = Vec::with_capacity(prog.len());
    let mut block_start = 0;
    // Mark branch-target offsets to avoid moving across them.
    let mut is_target = vec![false; prog.len() + 1];
    for (pos, i) in prog.iter().enumerate() {
        if matches!(format_of(i.op), Format::B | Format::J) {
            let t = pos as i64 + (i.imm as i64) / 4;
            if t >= 0 && (t as usize) < is_target.len() {
                is_target[t as usize] = true;
            }
        }
    }
    for pos in 0..prog.len() {
        let ends = is_barrier(prog[pos].op);
        let next_is_target = is_target.get(pos + 1).copied().unwrap_or(false);
        if ends || next_is_target || pos + 1 == prog.len() {
            let (body, ctl) = if ends {
                (&prog[block_start..pos], Some(prog[pos]))
            } else {
                (&prog[block_start..=pos], None)
            };
            out.extend(schedule_block(body));
            if let Some(c) = ctl {
                out.push(c);
            }
            block_start = pos + 1;
        }
    }
    debug_assert_eq!(out.len(), prog.len());
    out
}

/// Estimated stall cycles of a straight-line block under a simple in-order
/// model (used to quantify scheduling benefit in tests and benches).
pub fn estimate_stalls(prog: &[Instr]) -> u64 {
    let mut ready_at: std::collections::BTreeMap<(u8, u8), u64> = std::collections::BTreeMap::new();
    let mut cycle = 0u64;
    let mut stalls = 0u64;
    for i in prog {
        let (reads, writes) = reads_writes(i);
        let avail = reads
            .iter()
            .map(|r| ready_at.get(r).copied().unwrap_or(0))
            .max()
            .unwrap_or(0);
        if avail > cycle {
            stalls += avail - cycle;
            cycle = avail;
        }
        cycle += 1;
        for w in writes {
            ready_at.insert(w, cycle + latency(i.op) - 1);
        }
    }
    stalls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{kernels, KernelConfig};
    use crate::isa::encode::encode_all;
    use crate::isa::regs;
    use crate::sim::machine::Machine;
    use crate::sim::MachineConfig;
    use crate::util::rng::Rng;

    #[test]
    fn separates_dependent_pairs() {
        // load -> use, load -> use: scheduler should interleave the loads.
        let prog = vec![
            Instr::i(Op::Lw, 5, regs::SP, -4),
            Instr::i(Op::Addi, 6, 5, 1),
            Instr::i(Op::Lw, 7, regs::SP, -8),
            Instr::i(Op::Addi, 28, 7, 1),
        ];
        let before = estimate_stalls(&prog);
        let after = estimate_stalls(&schedule(&prog));
        assert!(after <= before);
    }

    #[test]
    fn preserves_dependences() {
        let prog = vec![
            Instr::i(Op::Addi, 5, 0, 10),
            Instr::i(Op::Addi, 5, 5, 5), // WAW+RAW on x5
            Instr::r(Op::Add, 6, 5, 5),
        ];
        let s = schedule(&prog);
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.run(&encode_all(&s).unwrap()).unwrap();
        assert_eq!(m.x[6], 30);
    }

    #[test]
    fn scheduled_matmul_still_correct() {
        let mach = MachineConfig::xgen_asic();
        let (mm, nn, kk) = (3, 9, 5);
        let mut rng = Rng::new(31);
        let a: Vec<f32> = (0..mm * kk).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..kk * nn).map(|_| rng.normal_f32()).collect();
        let art = kernels::matmul(&mach, KernelConfig::default(), mm, nn, kk, 0x1000, 0x4000, 0x8000, crate::ir::DType::F32).unwrap();
        let scheduled = schedule(&art.asm);
        assert_eq!(scheduled.len(), art.asm.len());
        let mut m = Machine::new(mach);
        m.write_f32_slice(0x1000, &a).unwrap();
        m.write_f32_slice(0x4000, &b).unwrap();
        m.run(&encode_all(&scheduled).unwrap()).unwrap();
        let got = m.read_f32_slice(0x8000, mm * nn).unwrap();
        for i in 0..mm {
            for j in 0..nn {
                let want: f32 = (0..kk).map(|x| a[i * kk + x] * b[x * nn + j]).sum();
                assert!((got[i * nn + j] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn memory_aware_order_is_topological() {
        use crate::frontend::{model_zoo, prepare};
        let g = prepare(model_zoo::resnet_cifar(1)).unwrap();
        let order = memory_aware_order(&g).unwrap();
        assert_eq!(order.len(), g.nodes.len());
        let mut pos = vec![0usize; g.nodes.len()];
        for (p, nid) in order.iter().enumerate() {
            pos[nid.0] = p;
        }
        let mut producer = std::collections::BTreeMap::new();
        for (i, node) in g.nodes.iter().enumerate() {
            for t in &node.outputs {
                producer.insert(*t, i);
            }
        }
        for (i, node) in g.nodes.iter().enumerate() {
            for t in &node.inputs {
                if let Some(&p) = producer.get(t) {
                    if p != i {
                        assert!(pos[p] < pos[i], "node {i} scheduled before its producer {p}");
                    }
                }
            }
        }
    }

    #[test]
    fn memory_aware_order_shrinks_fanout_peak() {
        // Four wide branches reduced pairwise: the original breadth-first
        // order holds all four branch buffers live at once; the memory-aware
        // order interleaves the reductions and frees two of them early.
        use crate::backend::memplan;
        use crate::frontend::prepare;
        use crate::ir::graph::Graph;
        use crate::ir::ops::{Attrs, OpKind};
        use crate::ir::shape::Shape;
        let mut g = Graph::new("fanout");
        let x = g.input("x", Shape::fixed(&[1, 1024]), crate::ir::DType::F32);
        let a1 = g.node(OpKind::Relu, "a1", &[x], Attrs::new());
        let a2 = g.node(OpKind::Sigmoid, "a2", &[x], Attrs::new());
        let a3 = g.node(OpKind::Abs, "a3", &[x], Attrs::new());
        let a4 = g.node(OpKind::Neg, "a4", &[x], Attrs::new());
        let s1 = g.node(OpKind::Add, "s1", &[a1, a2], Attrs::new());
        let s2 = g.node(OpKind::Add, "s2", &[a3, a4], Attrs::new());
        let out = g.node(OpKind::Add, "out", &[s1, s2], Attrs::new());
        g.outputs.push(out);
        let g = prepare(g).unwrap();
        let p0 = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
        let mut g2 = g.clone();
        let order = memory_aware_order(&g2).unwrap();
        apply_node_order(&mut g2, &order);
        let p1 = memplan::plan(&g2, 1 << 30, 2 << 30).unwrap();
        assert!(
            p1.dmem_peak < p0.dmem_peak,
            "reorder did not shrink peak: {} vs {}",
            p1.dmem_peak,
            p0.dmem_peak
        );
    }

    #[test]
    fn memory_aware_order_zoo_models_never_worse() {
        // The pipeline guarantee: the adopted order's measured peak is never
        // above the unscheduled baseline (the pipeline falls back to the
        // original order otherwise — mirrored here by taking the min).
        use crate::backend::memplan;
        use crate::frontend::{model_zoo, prepare};
        for g in [
            prepare(model_zoo::resnet_cifar(1)).unwrap(),
            prepare(model_zoo::mobilenet_cifar(1)).unwrap(),
        ] {
            let p0 = memplan::plan(&g, 1 << 30, 2 << 30).unwrap();
            let mut g2 = g.clone();
            let order = memory_aware_order(&g2).unwrap();
            apply_node_order(&mut g2, &order);
            let p1 = memplan::plan(&g2, 1 << 30, 2 << 30).unwrap();
            let adopted = p1.dmem_peak.min(p0.dmem_peak);
            assert!(adopted <= p0.dmem_peak);
            // Reordering must not lose or duplicate nodes.
            assert_eq!(g2.nodes.len(), g.nodes.len());
        }
    }

    #[test]
    fn property_schedule_is_permutation_per_block() {
        use crate::util::proptest::forall;
        forall("schedule permutes blocks", 50, |rng| {
            // Random straight-line int program (no control flow).
            let mut prog = Vec::new();
            for _ in 0..20 {
                let rd = rng.range(5, 16) as u8;
                let rs1 = rng.range(0, 16) as u8;
                match rng.index(3) {
                    0 => prog.push(Instr::i(Op::Addi, rd, rs1, rng.range(-100, 100) as i32)),
                    1 => prog.push(Instr::r(Op::Add, rd, rs1, rng.range(0, 16) as u8)),
                    _ => prog.push(Instr::r(Op::Mul, rd, rs1, rng.range(0, 16) as u8)),
                }
            }
            let s = schedule(&prog);
            if s.len() != prog.len() {
                return Err("length changed".into());
            }
            // Semantics: execute both and compare register files.
            let mut m1 = Machine::new(MachineConfig::xgen_asic());
            let mut m2 = Machine::new(MachineConfig::xgen_asic());
            m1.run(&encode_all(&prog).unwrap()).map_err(|e| format!("{e}"))?;
            m2.run(&encode_all(&s).unwrap()).map_err(|e| format!("{e}"))?;
            if m1.x != m2.x {
                return Err(format!("register state diverged: {:?} vs {:?}", m1.x, m2.x));
            }
            Ok(())
        });
    }
}
