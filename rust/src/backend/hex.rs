//! HEX file generation (a Table 1 feature row): Intel HEX records of the
//! encoded program, suitable for loading into an instruction ROM model.

use crate::isa::encode::encode_all;
use crate::isa::Instr;
use crate::util::error::Result;

/// One Intel HEX data record (type 00) for up to 16 bytes.
fn record(addr: u16, data: &[u8]) -> String {
    let mut sum: u8 = data.len() as u8;
    sum = sum
        .wrapping_add((addr >> 8) as u8)
        .wrapping_add(addr as u8);
    let mut s = format!(":{:02X}{:04X}00", data.len(), addr);
    for b in data {
        s.push_str(&format!("{b:02X}"));
        sum = sum.wrapping_add(*b);
    }
    s.push_str(&format!("{:02X}", (!sum).wrapping_add(1)));
    s
}

/// Encode a program as Intel HEX text (with extended linear address records
/// every 64 KiB).
pub fn to_intel_hex(prog: &[Instr]) -> Result<String> {
    let words = encode_all(prog)?;
    let mut out = String::new();
    let mut high: u32 = u32::MAX;
    let mut addr: u32 = 0;
    let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
    for chunk in bytes.chunks(16) {
        let h = addr >> 16;
        if h != high {
            high = h;
            let mut sum: u8 = 2 + 4;
            sum = sum.wrapping_add((h >> 8) as u8).wrapping_add(h as u8);
            out.push_str(&format!(":02000004{:04X}{:02X}\n", h, (!sum).wrapping_add(1)));
        }
        out.push_str(&record(addr as u16, chunk));
        out.push('\n');
        addr += chunk.len() as u32;
    }
    out.push_str(":00000001FF\n"); // EOF
    Ok(out)
}

/// Parse Intel HEX back to words — used for round-trip verification.
pub fn from_intel_hex(text: &str) -> Result<Vec<u32>> {
    let mut bytes = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if !line.starts_with(':') || line.len() < 11 {
            continue;
        }
        let n = u8::from_str_radix(&line[1..3], 16).unwrap_or(0) as usize;
        let rectype = &line[7..9];
        if rectype != "00" {
            continue;
        }
        for i in 0..n {
            let off = 9 + i * 2;
            bytes.push(u8::from_str_radix(&line[off..off + 2], 16).unwrap_or(0));
        }
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instr, Op};

    #[test]
    fn roundtrip() {
        let prog = vec![
            Instr::i(Op::Addi, 5, 0, 42),
            Instr::r(Op::Add, 6, 5, 5),
            Instr::u(Op::Lui, 7, 0x12345),
        ];
        let hex = to_intel_hex(&prog).unwrap();
        assert!(hex.starts_with(':'));
        assert!(hex.ends_with(":00000001FF\n"));
        let words = from_intel_hex(&hex).unwrap();
        assert_eq!(words, crate::isa::encode::encode_all(&prog).unwrap());
    }

    #[test]
    fn checksums_valid() {
        let prog = vec![Instr::i(Op::Addi, 5, 0, 1); 40];
        let hex = to_intel_hex(&prog).unwrap();
        for line in hex.lines() {
            let bytes: Vec<u8> = (1..line.len())
                .step_by(2)
                .map(|i| u8::from_str_radix(&line[i..i + 2], 16).unwrap())
                .collect();
            let sum: u8 = bytes.iter().fold(0u8, |a, b| a.wrapping_add(*b));
            assert_eq!(sum, 0, "checksum line {line}");
        }
    }
}
