//! Analytic kernel timing: estimates execution cycles from a loop-nest
//! profile instead of instruction-by-instruction replay.
//!
//! Zoo-scale models execute billions of MACs per inference — replaying them
//! through the functional machine during auto-tuning would dominate compile
//! time. The timing model walks the loop-nest structure that codegen emits,
//! charging per-class issue costs plus memory latencies from the analytic
//! cache-hit-rate model (paper §3.7, implemented in `cost::cache_model` and
//! shared here). The functional machine cross-validates this estimator on
//! small kernels (see `rust/tests/`).

use crate::isa::OpClass;
use crate::sim::MachineConfig;

/// Per-iteration instruction mix of one loop body (leaf work), held as a
/// fixed per-class array indexed by `OpClass::index()` — `add` is O(1) and
/// the cycle estimator walks a dense array instead of linearly scanning a
/// `Vec` of pairs (this structure sits under every tuner measurement).
#[derive(Debug, Clone)]
pub struct InstrMix {
    counts: [u64; OpClass::COUNT],
}

impl Default for InstrMix {
    fn default() -> Self {
        InstrMix { counts: [0; OpClass::COUNT] }
    }
}

impl InstrMix {
    pub fn add(&mut self, class: OpClass, n: u64) {
        self.counts[class.index()] += n;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Nonzero (class, count) pairs in class-index order.
    pub fn iter(&self) -> impl Iterator<Item = (OpClass, u64)> + '_ {
        OpClass::ALL
            .iter()
            .zip(self.counts.iter())
            .filter(|&(_, &n)| n != 0)
            .map(|(&c, &n)| (c, n))
    }
}

/// A loop nest: `trip` iterations of (body instruction mix + child loops).
#[derive(Debug, Clone, Default)]
pub struct LoopNest {
    pub trip: u64,
    pub body: InstrMix,
    pub children: Vec<LoopNest>,
    /// Loop-control overhead instructions per iteration (index bump, branch,
    /// address updates). Codegen sets this from the emitted structure;
    /// unrolling divides it.
    pub overhead: u64,
}

impl LoopNest {
    pub fn leaf(trip: u64, body: InstrMix, overhead: u64) -> LoopNest {
        LoopNest { trip, body, children: Vec::new(), overhead }
    }

    /// Total dynamic instruction count.
    pub fn instr_count(&self) -> u64 {
        let inner: u64 = self.children.iter().map(|c| c.instr_count()).sum();
        self.trip * (self.body.total() + self.overhead + inner)
    }
}

/// Memory-behavior summary of a kernel at a given schedule, produced by
/// codegen from tile sizes and tensor shapes. Hit rates come from the
/// cache-aware model (paper eq. 16).
#[derive(Debug, Clone)]
pub struct MemProfile {
    /// Total bytes loaded (after tiling reuse).
    pub load_bytes: u64,
    /// Total bytes stored.
    pub store_bytes: u64,
    /// Estimated hit rate per cache level (weighted model, eq. 16).
    pub level_hit_rates: Vec<f64>,
}

/// Issue cost (cycles at issue) per op class for the ASIC pipeline.
pub fn issue_cycles(cfg: &MachineConfig, class: OpClass, lmul: usize) -> f64 {
    let l = lmul.max(1) as f64;
    match class {
        OpClass::Alu => 1.0 / cfg.issue_width,
        OpClass::Branch | OpClass::Jump => 1.0 / cfg.issue_width,
        OpClass::Mul => 1.0,
        OpClass::Div => 20.0,
        OpClass::Load | OpClass::Store => 1.0, // latency added via MemProfile
        OpClass::FAlu => 1.0,
        OpClass::FMul => 1.0,
        OpClass::FDiv => 16.0,
        OpClass::FMa => 1.0,
        OpClass::FCustom => 8.0,
        OpClass::VSet => 1.0,
        // One beat per register in the group, spread over parallel pipes.
        OpClass::VLoad | OpClass::VStore => l / cfg.vector_pipes.max(1.0),
        OpClass::VAlu => l / cfg.vector_pipes.max(1.0),
        OpClass::VMul => l / cfg.vector_pipes.max(1.0),
        OpClass::VFma => l / cfg.vector_pipes.max(1.0),
        OpClass::VRed => 4.0 + l / cfg.vector_pipes.max(1.0),
    }
}

/// Estimate total cycles for a kernel: compute cycles from the loop nest +
/// memory stall cycles from the profile.
pub fn estimate_cycles(cfg: &MachineConfig, nest: &LoopNest, mem: &MemProfile, lmul: usize) -> f64 {
    let compute = nest_cycles(cfg, nest, lmul);
    let stalls = memory_stall_cycles(cfg, mem);
    // Simple overlap model: the in-order pipeline hides a fraction of memory
    // latency under compute (deep-enough load queue); the rest stalls.
    const OVERLAP: f64 = 0.6;
    compute + stalls * (1.0 - OVERLAP)
}

fn nest_cycles(cfg: &MachineConfig, nest: &LoopNest, lmul: usize) -> f64 {
    let body: f64 = nest
        .body
        .iter()
        .map(|(c, n)| n as f64 * issue_cycles(cfg, c, lmul))
        .sum();
    let inner: f64 = nest.children.iter().map(|c| nest_cycles(cfg, c, lmul)).sum();
    nest.trip as f64 * (body + nest.overhead as f64 / cfg.issue_width + inner)
}

/// Average memory access latency given weighted level hit rates (eq. 16) and
/// the resulting stall cycles for the kernel's traffic.
pub fn memory_stall_cycles(cfg: &MachineConfig, mem: &MemProfile) -> f64 {
    let line = cfg.caches.first().map(|c| c.line).unwrap_or(64) as f64;
    let accesses = (mem.load_bytes + mem.store_bytes) as f64 / line;
    let mut remaining = 1.0;
    let mut avg_latency = 0.0;
    for (i, cache) in cfg.caches.iter().enumerate() {
        let hr = mem.level_hit_rates.get(i).copied().unwrap_or(0.0);
        avg_latency += remaining * hr * cache.latency as f64;
        remaining *= 1.0 - hr;
    }
    avg_latency += remaining * cfg.mem_latency as f64;
    accesses * avg_latency
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MachineConfig {
        MachineConfig::xgen_asic()
    }

    fn fma_body(n: u64) -> InstrMix {
        let mut m = InstrMix::default();
        m.add(OpClass::VFma, n);
        m
    }

    #[test]
    fn instr_mix_accumulates_per_class() {
        let mut m = InstrMix::default();
        m.add(OpClass::VFma, 2);
        m.add(OpClass::Alu, 1);
        m.add(OpClass::VFma, 3);
        assert_eq!(m.total(), 6);
        // iter() yields nonzero classes in index order, folded per class.
        let pairs: Vec<(OpClass, u64)> = m.iter().collect();
        assert_eq!(pairs, vec![(OpClass::Alu, 1), (OpClass::VFma, 5)]);
    }

    #[test]
    fn instr_count_nested() {
        let inner = LoopNest::leaf(10, fma_body(2), 2);
        let outer = LoopNest { trip: 5, body: InstrMix::default(), children: vec![inner], overhead: 3 };
        // 5 * (3 + 10*(2+2)) = 215
        assert_eq!(outer.instr_count(), 215);
    }

    #[test]
    fn more_work_more_cycles() {
        let mem = MemProfile { load_bytes: 0, store_bytes: 0, level_hit_rates: vec![1.0, 0.0] };
        let small = estimate_cycles(&cfg(), &LoopNest::leaf(10, fma_body(1), 2), &mem, 1);
        let big = estimate_cycles(&cfg(), &LoopNest::leaf(100, fma_body(1), 2), &mem, 1);
        assert!(big > 9.0 * small);
    }

    #[test]
    fn unrolling_reduces_overhead_cycles() {
        let mem = MemProfile { load_bytes: 0, store_bytes: 0, level_hit_rates: vec![1.0] };
        // Same work, unrolled x4: quarter the trips, 4x body, same overhead/iter.
        let rolled = LoopNest::leaf(100, fma_body(1), 3);
        let unrolled = LoopNest::leaf(25, fma_body(4), 3);
        let c1 = estimate_cycles(&cfg(), &rolled, &mem, 1);
        let c2 = estimate_cycles(&cfg(), &unrolled, &mem, 1);
        assert!(c2 < c1, "{c2} vs {c1}");
    }

    #[test]
    fn better_hit_rate_fewer_stalls() {
        let hot = MemProfile { load_bytes: 1 << 20, store_bytes: 0, level_hit_rates: vec![0.95, 0.8] };
        let cold = MemProfile { load_bytes: 1 << 20, store_bytes: 0, level_hit_rates: vec![0.5, 0.5] };
        assert!(
            memory_stall_cycles(&cfg(), &hot) < memory_stall_cycles(&cfg(), &cold)
        );
    }

    #[test]
    fn lmul_scales_vector_issue() {
        // Beats scale with the register group and spread over the pipes.
        let pipes = cfg().vector_pipes;
        assert_eq!(issue_cycles(&cfg(), OpClass::VFma, 4), 4.0 / pipes);
        assert_eq!(issue_cycles(&cfg(), OpClass::VFma, 1), 1.0 / pipes);
        assert!(issue_cycles(&cfg(), OpClass::VFma, 4) > issue_cycles(&cfg(), OpClass::VFma, 1));
    }
}
