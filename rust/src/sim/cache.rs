//! Set-associative cache simulator (LRU) — one instance per level, chained
//! into a hierarchy. Tracks hits/misses/energy per level; the functional
//! machine drives it with real addresses, and `asic::ppa` reads the counters
//! for the energy model.

/// Static parameters of one cache level.
#[derive(Debug, Clone)]
pub struct CacheParams {
    pub name: &'static str,
    /// Capacity in bytes.
    pub size: usize,
    /// Line size in bytes.
    pub line: usize,
    /// Associativity (ways).
    pub assoc: usize,
    /// Hit latency in cycles.
    pub latency: u64,
    /// Energy per access in picojoules.
    pub energy_pj: f64,
}

impl CacheParams {
    pub fn num_sets(&self) -> usize {
        (self.size / self.line / self.assoc).max(1)
    }
}

/// One simulated level: tag store with LRU stamps.
#[derive(Debug, Clone)]
struct Level {
    params: CacheParams,
    /// Set count, computed once (the hot path used to re-derive it — three
    /// integer divisions — on every access).
    sets: u64,
    /// `(line_shift, set_shift)` when the line size and set count are both
    /// powers of two (true for every shipped config): the address → set/tag
    /// split becomes shifts and a mask instead of u64 divisions.
    shifts: Option<(u32, u32)>,
    /// tags[set * assoc + way] = Some(tag)
    tags: Vec<Option<u64>>,
    /// LRU stamps, parallel to `tags`.
    stamps: Vec<u64>,
    hits: u64,
    misses: u64,
    tick: u64,
}

impl Level {
    fn new(params: CacheParams) -> Level {
        let slots = params.num_sets() * params.assoc;
        let sets = params.num_sets() as u64;
        let shifts = if params.line.is_power_of_two() && sets.is_power_of_two() {
            Some((params.line.trailing_zeros(), sets.trailing_zeros()))
        } else {
            None
        };
        Level {
            params,
            sets,
            shifts,
            tags: vec![None; slots],
            stamps: vec![0; slots],
            hits: 0,
            misses: 0,
            tick: 0,
        }
    }

    /// Access a line address; true = hit (and refreshes LRU), false = miss
    /// (and fills).
    #[inline]
    fn access(&mut self, addr: u64) -> bool {
        self.tick += 1;
        let (set, tag) = match self.shifts {
            Some((line_shift, set_shift)) => {
                let line = addr >> line_shift;
                (((line & (self.sets - 1)) as usize), line >> set_shift)
            }
            None => {
                let line = addr / self.params.line as u64;
                (((line % self.sets) as usize), line / self.sets)
            }
        };
        let base = set * self.params.assoc;
        let ways = &self.tags[base..base + self.params.assoc];
        if let Some(w) = ways.iter().position(|t| *t == Some(tag)) {
            self.hits += 1;
            self.stamps[base + w] = self.tick;
            return true;
        }
        self.misses += 1;
        // Fill LRU way.
        let lru = (0..self.params.assoc)
            .min_by_key(|&w| self.stamps[base + w])
            .unwrap();
        self.tags[base + lru] = Some(tag);
        self.stamps[base + lru] = self.tick;
        false
    }
}

/// Per-level counters snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub name: String,
    pub hits: u64,
    pub misses: u64,
    pub energy_pj: f64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A cache hierarchy (L1 → L2 → L3 → memory).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    levels: Vec<Level>,
    mem_latency: u64,
    /// Energy of a backing-memory access (DRAM / big SRAM macro).
    pub mem_energy_pj: f64,
    pub mem_accesses: u64,
}

impl Hierarchy {
    pub fn new(params: &[CacheParams], mem_latency: u64) -> Hierarchy {
        Hierarchy {
            levels: params.iter().cloned().map(Level::new).collect(),
            mem_latency,
            mem_energy_pj: 640.0,
            mem_accesses: 0,
        }
    }

    /// Access one byte address; returns the total latency in cycles.
    #[inline]
    pub fn access(&mut self, addr: u64) -> u64 {
        let mut latency = 0;
        for lvl in self.levels.iter_mut() {
            latency += lvl.params.latency;
            if lvl.access(addr) {
                return latency;
            }
        }
        self.mem_accesses += 1;
        latency + self.mem_latency
    }

    pub fn stats(&self) -> Vec<CacheStats> {
        self.levels
            .iter()
            .map(|l| CacheStats {
                name: l.params.name.to_string(),
                hits: l.hits,
                misses: l.misses,
                energy_pj: (l.hits + l.misses) as f64 * l.params.energy_pj,
            })
            .collect()
    }

    /// Total memory-system energy in picojoules.
    pub fn energy_pj(&self) -> f64 {
        self.stats().iter().map(|s| s.energy_pj).sum::<f64>()
            + self.mem_accesses as f64 * self.mem_energy_pj
    }

    pub fn reset_stats(&mut self) {
        for l in self.levels.iter_mut() {
            l.hits = 0;
            l.misses = 0;
        }
        self.mem_accesses = 0;
    }

    /// Full reset: counters *and* tag/LRU state. A reused machine must
    /// measure the same cycles as a fresh one, and latency depends on which
    /// lines are warm — `reset_stats` alone would leave the previous
    /// request's working set resident.
    pub fn reset(&mut self) {
        for l in self.levels.iter_mut() {
            l.tags.fill(None);
            l.stamps.fill(0);
            l.tick = 0;
        }
        self.reset_stats();
    }
}

// ---------------------------------------------------------------------------
// Analytic hit-rate model (paper §3.7 / eq. 16) — the fast estimate used on
// the tuning path; the simulated hierarchy above is ground truth.
// ---------------------------------------------------------------------------

/// Base L1 hit rates by access pattern (paper §3.7: "Sequential operations
/// achieve 95% L1 hit rate, while random access patterns achieve 70%").
pub const SEQ_L1_HIT: f64 = 0.95;
pub const RAND_L1_HIT: f64 = 0.70;
/// Max hit-rate improvement from effective tiling (paper: "up to 15%").
pub const TILING_MAX_BOOST: f64 = 0.15;

/// Per-level hit-rate estimate for a kernel with the given working-set size,
/// access pattern, and tiling effectiveness in [0, 1].
///
/// Eq. 16: the weighted hit rate is Σ portionᵢ · hit_rateᵢ where portionᵢ is
/// the fraction of the working set resident at level i; here we return the
/// per-level rates (the weighting happens in `timing::memory_stall_cycles`).
pub fn analytic_hit_rates(
    caches: &[CacheParams],
    working_set_bytes: usize,
    sequential: bool,
    tiling_effectiveness: f64,
) -> Vec<f64> {
    let base = if sequential { SEQ_L1_HIT } else { RAND_L1_HIT };
    let boost = TILING_MAX_BOOST * tiling_effectiveness.clamp(0.0, 1.0);
    let mut rates = Vec::with_capacity(caches.len());
    for (i, c) in caches.iter().enumerate() {
        // Capacity pressure: working sets far beyond a level's size thrash it.
        let pressure = working_set_bytes as f64 / c.size as f64;
        let capacity_factor = if pressure <= 1.0 {
            1.0
        } else {
            // Falls toward the streaming floor (1 miss per line).
            (1.0 / pressure).max(1.0 - 1.0 / (c.line as f64 / 4.0))
        };
        // Deeper levels see only the misses of shallower ones; their base
        // rate improves because the reuse distance filter already applied.
        let level_base = (base + 0.02 * i as f64).min(0.99);
        rates.push(((level_base + boost) * capacity_factor).clamp(0.0, 0.995));
    }
    rates
}

/// Tiling effectiveness (paper §3.7): how well the chosen tiles fit L1.
/// 1.0 = tile working set comfortably resident, decaying as it overflows.
pub fn tiling_effectiveness(caches: &[CacheParams], tile_bytes: usize) -> f64 {
    let l1 = caches.first().map(|c| c.size).unwrap_or(32 << 10) as f64;
    let ratio = tile_bytes as f64 / l1;
    if ratio <= 0.5 {
        1.0
    } else if ratio <= 1.0 {
        2.0 - 2.0 * ratio // linear fade 1 -> 0 as tile fills L1
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hierarchy {
        Hierarchy::new(
            &[
                CacheParams { name: "L1", size: 256, line: 64, assoc: 2, latency: 2, energy_pj: 1.0 },
                CacheParams { name: "L2", size: 1024, line: 64, assoc: 2, latency: 10, energy_pj: 5.0 },
            ],
            100,
        )
    }

    #[test]
    fn repeated_access_hits_l1() {
        let mut h = tiny();
        let cold = h.access(0x100);
        let warm = h.access(0x100);
        assert!(cold > warm);
        assert_eq!(warm, 2);
        assert_eq!(h.stats()[0].hits, 1);
    }

    #[test]
    fn same_line_shares_entry() {
        let mut h = tiny();
        h.access(0x100);
        assert_eq!(h.access(0x13F), 2); // same 64-byte line
        assert_eq!(h.access(0x140), 2 + 10 + 100); // next line: full miss
    }

    #[test]
    fn lru_eviction() {
        let mut h = tiny();
        // L1: 256B/64B/2-way = 2 sets. Lines mapping to set 0: 0, 128, 256...
        h.access(0); // fill way 0
        h.access(128); // fill way 1
        h.access(0); // refresh 0
        h.access(256); // evicts 128 (LRU)
        assert_eq!(h.access(0), 2, "0 must still be resident");
        assert!(h.access(128) > 2, "128 must have been evicted");
    }

    #[test]
    fn sequential_streaming_hit_rate() {
        let mut h = tiny();
        for i in 0..4096u64 {
            h.access(i);
        }
        let s = &h.stats()[0];
        // 1 miss per 64-byte line -> 63/64 hit rate.
        assert!(s.hit_rate() > 0.97, "{}", s.hit_rate());
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut h = tiny();
        // Stride-64 loop over 8 KiB (128 lines) reused twice: L1 (4 lines)
        // and L2 (16 lines) both too small -> second pass still misses.
        for _ in 0..2 {
            for i in 0..128u64 {
                h.access(i * 64);
            }
        }
        assert!(h.stats()[0].hit_rate() < 0.05);
        assert!(h.mem_accesses > 200);
    }

    #[test]
    fn non_pow2_geometry_uses_division_fallback() {
        // 96-byte lines: the shift fast path can't apply, the division
        // fallback must still model a 1-set, 3-way LRU correctly.
        let mut h = Hierarchy::new(
            &[CacheParams { name: "L1", size: 288, line: 96, assoc: 3, latency: 2, energy_pj: 1.0 }],
            50,
        );
        h.access(0);
        h.access(96);
        h.access(192);
        assert_eq!(h.access(0), 2, "line 0 resident after fills");
        assert!(h.access(288) > 2, "fourth line must miss");
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut h = tiny();
        let cold = h.access(0x100);
        assert!(h.access(0x100) < cold, "second access must be warm");
        h.reset();
        assert_eq!(h.access(0x100), cold, "reset must evict warm lines");
        assert_eq!(h.stats()[0].misses, 1, "reset must clear counters too");
    }

    #[test]
    fn energy_accumulates() {
        let mut h = tiny();
        h.access(0);
        h.access(0);
        assert!(h.energy_pj() > 0.0);
        h.reset_stats();
        assert_eq!(h.energy_pj(), 0.0);
    }

    #[test]
    fn analytic_model_paper_constants() {
        let caches = crate::sim::MachineConfig::xgen_asic().caches;
        let seq = analytic_hit_rates(&caches, 8 << 10, true, 0.0);
        let rand = analytic_hit_rates(&caches, 8 << 10, false, 0.0);
        assert!((seq[0] - 0.95).abs() < 1e-9, "paper: sequential L1 = 95%");
        assert!((rand[0] - 0.70).abs() < 1e-9, "paper: random L1 = 70%");
        // Tiling adds up to 15 points.
        let tiled = analytic_hit_rates(&caches, 8 << 10, false, 1.0);
        assert!((tiled[0] - 0.85).abs() < 1e-9);
    }

    #[test]
    fn analytic_model_capacity_pressure() {
        let caches = crate::sim::MachineConfig::xgen_asic().caches;
        let small = analytic_hit_rates(&caches, 8 << 10, true, 0.0);
        let huge = analytic_hit_rates(&caches, 64 << 20, true, 0.0);
        assert!(huge[0] < small[0]);
        assert!(huge[1] < small[1]);
    }

    #[test]
    fn tiling_effectiveness_fades_with_size() {
        let caches = crate::sim::MachineConfig::xgen_asic().caches; // 32K L1
        assert_eq!(tiling_effectiveness(&caches, 8 << 10), 1.0);
        let half = tiling_effectiveness(&caches, 24 << 10);
        assert!(half > 0.0 && half < 1.0);
        assert_eq!(tiling_effectiveness(&caches, 64 << 10), 0.0);
    }
}
