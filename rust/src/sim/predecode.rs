//! One-shot predecode of an encoded binary into a flat micro-op program.
//!
//! [`crate::isa::decode`] is exact but per-word; calling it on every fetch
//! made the interpreter the bottleneck of the whole measurement loop. Here
//! the binary is decoded **once** into a `Vec` of resolved [`MicroOp`]s and
//! everything that doesn't depend on runtime state is folded in up front:
//!
//! * branch / `jal` displacements become *instruction indices* ([`MicroOp::target`]),
//! * `lui`/`auipc` results and `jal`/`jalr` link values are precomputed
//!   ([`MicroOp::aux`]),
//! * register fields widen to `usize` (no per-step casts),
//! * the [`OpClass`] rides along so the dispatch loop never re-derives it.
//!
//! Words that don't decode become [`Slot::Illegal`] and raise an error only
//! if the program actually executes them — the same lazy-fetch semantics as
//! the decode-per-step loop, so data or padding after the final retired
//! instruction stays harmless.

use crate::isa::{decode, Op, OpClass};

/// Sentinel for [`MicroOp::target`]: the taken-target address is not
/// word-aligned, which is a fault **only if the branch is actually taken**
/// (the raw address sits in [`MicroOp::aux`] for the fault message).
pub const MISALIGNED_TARGET: usize = usize::MAX;

/// A resolved micro-op: one decoded instruction with its operand fields
/// widened and its statically-knowable results folded in.
#[derive(Debug, Clone, Copy)]
pub struct MicroOp {
    pub op: Op,
    pub class: OpClass,
    pub rd: usize,
    pub rs1: usize,
    pub rs2: usize,
    pub rs3: usize,
    pub imm: i32,
    /// Branches and `jal`: the taken-target *instruction index*. An index
    /// at or beyond the program length means "halt" (fall off the end),
    /// exactly like a taken branch past the last word;
    /// [`MISALIGNED_TARGET`] means a taken branch faults. Zero elsewhere.
    pub target: usize,
    /// `lui`: `imm << 12`; `auipc`: `pc + (imm << 12)`; `jal`/`jalr`: the
    /// link value (`pc + 4`); conditional branches: the raw taken-target
    /// byte address (used in misalignment fault messages). Zero elsewhere.
    pub aux: u32,
}

/// One program slot: a decoded micro-op, or a fault that fires only when
/// the slot is actually executed.
#[derive(Debug, Clone, Copy)]
pub enum Slot {
    Op(MicroOp),
    /// The word failed to decode (kept verbatim for the error message).
    Illegal(u32),
    /// A `jal` whose (unconditional) target address is not word-aligned:
    /// the encoding permits 2-byte multiples, this machine has no
    /// compressed instructions, so executing the slot is always a fault.
    /// Conditional branches with misaligned targets stay [`Slot::Op`] and
    /// fault only when taken (see [`MISALIGNED_TARGET`]).
    Misaligned(u32),
}

/// A predecoded program, ready for `Machine::run_predecoded`.
#[derive(Debug, Clone)]
pub struct Predecoded {
    pub slots: Vec<Slot>,
}

impl MicroOp {
    /// True for ops that end a basic block: conditional branches and jumps.
    pub fn is_control(&self) -> bool {
        matches!(self.class, OpClass::Branch | OpClass::Jump)
    }

    /// True for conditional branches (fall-through + taken successor).
    pub fn is_cond_branch(&self) -> bool {
        self.class == OpClass::Branch
    }

    /// The statically-resolved taken-target instruction index, if this op
    /// has one (conditional branches and `jal`). `jalr` has a runtime
    /// target and returns `None`; [`MISALIGNED_TARGET`] is passed through
    /// for the caller to treat as a taken-path fault.
    pub fn taken_target(&self) -> Option<usize> {
        match self.op {
            Op::Beq | Op::Bne | Op::Blt | Op::Bge | Op::Jal => Some(self.target),
            _ => None,
        }
    }

    /// Whether control can continue to the next instruction after this op
    /// executes (everything except unconditional jumps).
    pub fn falls_through(&self) -> bool {
        !matches!(self.op, Op::Jal | Op::Jalr)
    }
}

impl Predecoded {
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Static successor instruction indices of the slot at `idx`, as
    /// `(fall_through, taken)`. Either entry is `None` when that edge does
    /// not exist or leaves the program (an index `>= len` halts, so the
    /// halt edge is represented as `None`). Faulting slots
    /// ([`Slot::Illegal`], [`Slot::Misaligned`]) and `jalr` (runtime
    /// target) have no static successors; a conditional branch whose taken
    /// target is [`MISALIGNED_TARGET`] keeps only its fall-through edge.
    pub fn successors(&self, idx: usize) -> (Option<usize>, Option<usize>) {
        let len = self.slots.len();
        let u = match &self.slots[idx] {
            Slot::Op(u) => u,
            Slot::Illegal(_) | Slot::Misaligned(_) => return (None, None),
        };
        let fall = match u.falls_through() && idx + 1 < len {
            true => Some(idx + 1),
            false => None,
        };
        let taken = u.taken_target().filter(|&t| t < len);
        (fall, taken)
    }
}

/// Predecode one word sitting at instruction index `idx`. Infallible:
/// undecodable words become [`Slot::Illegal`].
pub fn predecode_one(word: u32, idx: usize) -> Slot {
    let i = match decode::decode(word) {
        Ok(i) => i,
        Err(_) => return Slot::Illegal(word),
    };
    let pc = (idx as u32).wrapping_mul(4);
    let mut u = MicroOp {
        op: i.op,
        class: i.op.class(),
        rd: i.rd as usize,
        rs1: i.rs1 as usize,
        rs2: i.rs2 as usize,
        rs3: i.rs3 as usize,
        imm: i.imm,
        target: 0,
        aux: 0,
    };
    match i.op {
        Op::Lui => u.aux = (i.imm as u32) << 12,
        Op::Auipc => u.aux = pc.wrapping_add((i.imm as u32) << 12),
        Op::Jalr => u.aux = pc.wrapping_add(4),
        Op::Jal => {
            u.aux = pc.wrapping_add(4);
            let t = pc.wrapping_add(i.imm as u32);
            if t % 4 != 0 {
                return Slot::Misaligned(t);
            }
            u.target = (t / 4) as usize;
        }
        Op::Beq | Op::Bne | Op::Blt | Op::Bge => {
            let t = pc.wrapping_add(i.imm as u32);
            u.aux = t;
            u.target = if t % 4 == 0 {
                (t / 4) as usize
            } else {
                MISALIGNED_TARGET
            };
        }
        _ => {}
    }
    Slot::Op(u)
}

/// Predecode a whole encoded program.
pub fn predecode(prog: &[u32]) -> Predecoded {
    Predecoded {
        slots: prog
            .iter()
            .enumerate()
            .map(|(idx, &w)| predecode_one(w, idx))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode_all;
    use crate::isa::Instr;

    fn words(prog: &[Instr]) -> Vec<u32> {
        encode_all(prog).unwrap()
    }

    #[test]
    fn branch_and_jump_targets_resolve_to_indices() {
        // 0: addi; 1: bne -4 (-> idx 0); 2: jal +8 (-> idx 4)
        let w = words(&[
            Instr::i(Op::Addi, 5, 0, 1),
            Instr::b(Op::Bne, 5, 0, -4),
            Instr::u(Op::Jal, 1, 8),
        ]);
        let p = predecode(&w);
        match p.slots[1] {
            Slot::Op(u) => {
                assert_eq!(u.op, Op::Bne);
                assert_eq!(u.target, 0);
            }
            _ => panic!("bne should predecode"),
        }
        match p.slots[2] {
            Slot::Op(u) => {
                assert_eq!(u.op, Op::Jal);
                assert_eq!(u.target, 4, "jal +8 from pc=8 lands at word 4");
                assert_eq!(u.aux, 12, "link value is pc + 4");
            }
            _ => panic!("jal should predecode"),
        }
    }

    #[test]
    fn lui_and_auipc_constants_fold() {
        let w = words(&[Instr::u(Op::Lui, 5, 0x12345), Instr::u(Op::Auipc, 6, 1)]);
        let p = predecode(&w);
        match p.slots[0] {
            Slot::Op(u) => assert_eq!(u.aux, 0x12345 << 12),
            _ => panic!(),
        }
        match p.slots[1] {
            Slot::Op(u) => assert_eq!(u.aux, 4 + (1 << 12), "pc=4 folded in"),
            _ => panic!(),
        }
    }

    #[test]
    fn illegal_words_become_lazy_faults() {
        let p = predecode(&[0xFFFF_FFFF, 0x0000_0000]);
        assert!(matches!(p.slots[0], Slot::Illegal(0xFFFF_FFFF)));
        assert!(matches!(p.slots[1], Slot::Illegal(0)));
    }

    #[test]
    fn register_fields_widen() {
        let w = words(&[Instr::r(Op::Add, 7, 8, 9)]);
        match predecode(&w).slots[0] {
            Slot::Op(u) => {
                assert_eq!((u.rd, u.rs1, u.rs2), (7, 8, 9));
                assert_eq!(u.class, OpClass::Alu);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn misaligned_branch_target_is_a_lazy_sentinel_not_a_slot_fault() {
        // B-format permits 2-byte multiples; +6 is encodable but lands
        // mid-word. The slot must stay executable (fault only if taken).
        let w = words(&[Instr::b(Op::Beq, 1, 2, 6)]);
        match predecode(&w).slots[0] {
            Slot::Op(u) => {
                assert_eq!(u.target, MISALIGNED_TARGET);
                assert_eq!(u.aux, 6, "raw address kept for the fault message");
            }
            _ => panic!("conditional branch must not fault at predecode"),
        }
    }

    #[test]
    fn misaligned_jal_target_faults_the_slot() {
        // jal is unconditional: executing the slot always faults.
        let w = words(&[Instr::u(Op::Jal, 1, 6)]);
        assert!(matches!(predecode(&w).slots[0], Slot::Misaligned(6)));
    }

    #[test]
    fn branch_past_the_end_halts_via_large_index() {
        // bne +16 from pc=0 -> word index 4 in a 1-word program: the
        // dispatch loop's `idx < len` bound turns that into a halt.
        let w = words(&[Instr::b(Op::Bne, 5, 0, 16)]);
        match predecode(&w).slots[0] {
            Slot::Op(u) => assert!(u.target >= 1),
            _ => panic!(),
        }
    }
}
