//! Energy accounting: per-op-class switching energy (scaled by datapath
//! precision) + memory-hierarchy energy + static leakage. Feeds the PPA
//! model (`asic::ppa`) that reproduces the paper's power columns.
//!
//! First-order, constants documented inline; DESIGN.md §Substitutions
//! explains why relative (not absolute) fidelity is the goal.

use crate::ir::dtype::DType;
use crate::isa::OpClass;
use crate::sim::MachineConfig;

/// Base dynamic energy per operation in picojoules for a 32-bit datapath at
/// a mature planar node (ballpark: Horowitz ISSCC'14 scaled).
pub fn base_energy_pj(class: OpClass) -> f64 {
    match class {
        OpClass::Alu => 0.5,
        OpClass::Mul => 3.0,
        OpClass::Div => 12.0,
        OpClass::Branch | OpClass::Jump => 0.4,
        OpClass::Load | OpClass::Store => 1.0, // port energy; array energy in cache model
        OpClass::FAlu => 1.2,
        OpClass::FMul => 3.5,
        OpClass::FDiv => 14.0,
        OpClass::FMa => 4.2,
        OpClass::FCustom => 6.0,
        OpClass::VSet => 0.3,
        OpClass::VLoad | OpClass::VStore => 4.0, // 8 lanes moving
        OpClass::VAlu => 2.8,   // 8 lanes x ~0.35
        OpClass::VMul => 9.0,
        OpClass::VFma => 12.0,  // 8 FMA lanes
        OpClass::VRed => 4.0,
    }
}

/// Switching-energy scale factor vs the 32-bit datapath for a precision:
/// multiplier energy scales ~quadratically with operand width, adders and
/// wires ~linearly; we use an intermediate exponent of 1.6 (empirically
/// between the two) and clamp Binary to the XNOR-popcount floor.
pub fn precision_energy_scale(dt: DType) -> f64 {
    let bits = dt.bits() as f64;
    ((bits / 32.0).powf(1.6)).max(0.01)
}

/// Dynamic energy of an instruction mix at a given datapath precision.
pub fn dynamic_energy_pj(counts: &[(OpClass, u64)], dt: DType) -> f64 {
    let scale = precision_energy_scale(dt);
    counts
        .iter()
        .map(|(c, n)| {
            let arith = matches!(
                c,
                OpClass::Mul
                    | OpClass::FMul
                    | OpClass::FMa
                    | OpClass::VMul
                    | OpClass::VFma
                    | OpClass::VAlu
                    | OpClass::FAlu
            );
            let s = if arith { scale } else { 1.0 };
            *n as f64 * base_energy_pj(*c) * s
        })
        .sum()
}

/// Static (leakage) power in milliwatts — proportional to on-die SRAM and
/// datapath width.
pub fn static_power_mw(cfg: &MachineConfig) -> f64 {
    let sram_kb = cfg.caches.iter().map(|c| c.size).sum::<usize>() as f64 / 1024.0;
    // ~12 µW/KB SRAM leakage + 10 mW core floor (scaled up for wide OoO).
    0.012 * sram_kb + 10.0 * cfg.issue_width
}

/// Average power given total dynamic energy (pJ) over a runtime (seconds).
pub fn average_power_mw(cfg: &MachineConfig, dynamic_pj: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return static_power_mw(cfg);
    }
    dynamic_pj * 1e-12 / seconds * 1e3 + static_power_mw(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_scaling_monotone() {
        let e32 = precision_energy_scale(DType::F32);
        let e8 = precision_energy_scale(DType::I8);
        let e1 = precision_energy_scale(DType::Binary);
        assert!((e32 - 1.0).abs() < 1e-12);
        assert!(e8 < e32 / 4.0, "int8 should save >4x on arith energy");
        assert!(e1 < e8);
        assert!(e1 >= 0.01);
    }

    #[test]
    fn quantized_mix_cheaper() {
        let mix = vec![(OpClass::VFma, 1_000_000u64), (OpClass::VLoad, 100_000u64)];
        let fp32 = dynamic_energy_pj(&mix, DType::F32);
        let int8 = dynamic_energy_pj(&mix, DType::I8);
        assert!(int8 < fp32 * 0.35, "{int8} vs {fp32}");
    }

    #[test]
    fn average_power_reasonable_range() {
        let cfg = MachineConfig::xgen_asic();
        // 10 ms inference burning 3 mJ -> 300 mW dynamic + leakage.
        let p = average_power_mw(&cfg, 3e9, 0.01);
        assert!((300.0..400.0).contains(&p), "{p}");
    }

    #[test]
    fn cpu_leaks_more_than_asic() {
        assert!(
            static_power_mw(&MachineConfig::cpu_a78())
                > static_power_mw(&MachineConfig::xgen_asic())
        );
    }
}
