//! The simulated accelerator (DESIGN.md §Substitutions: the paper evaluates
//! on proprietary silicon; we build the closest measurable equivalent).
//!
//! * [`machine`] — functional RV32I+RVV executor: runs *encoded* binaries
//!   with DMEM/WMEM, three register files, and per-instruction cycle +
//!   cache accounting. This is the correctness oracle for generated code
//!   and the "hardware measurement" the learned cost model trains against.
//!   Binaries are decoded **once** into micro-ops ([`predecode`]) and then
//!   driven by an index-based dispatch loop; the naive decode-per-step
//!   loop survives as `Machine::run_reference` for differential testing.
//! * [`predecode`] — one-shot binary → micro-op lowering for the fast path.
//! * [`fault`] — typed machine traps ([`fault::Trap`]) and the seeded
//!   fault-injection harness ([`fault::FaultPlan`]) the fault-tolerant
//!   serving stack is proven against.
//! * [`cache`] — set-associative L1/L2/L3 cache simulator (LRU).
//! * [`timing`] — analytic kernel timing: estimates cycles from a loop-nest
//!   profile without instruction-by-instruction replay. This is what the
//!   auto-tuner calls thousands of times; the functional machine
//!   cross-validates it on small kernels.
//! * [`power`] — energy accounting (per-op-class + memory-hierarchy energy)
//!   feeding the PPA model in [`crate::asic`].

pub mod cache;
pub mod fault;
pub mod machine;
pub mod power;
pub mod predecode;
pub mod timing;

use crate::ir::dtype::DType;

/// Machine configuration: the accelerator (or baseline platform) being
/// simulated. All PPA-relevant constants live here and in
/// `asic::params`.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub name: String,
    /// Vector register width in bits (VLEN). 256 = 8 f32 lanes.
    pub vlen_bits: usize,
    /// Whether the RVV subset is available (the CPU baseline is scalar-only
    /// in vector terms — it models a generic OoO core).
    pub has_vector: bool,
    /// Activation memory size in bytes.
    pub dmem_bytes: usize,
    /// Weight memory size in bytes.
    pub wmem_bytes: usize,
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
    /// Scalar instructions issued per cycle (models superscalar baselines).
    pub issue_width: f64,
    /// Parallel vector pipelines (the ASIC's MAC-array width beyond one
    /// VLEN lane group; the paper never discloses its array size — this is
    /// the knob DESIGN.md §Substitutions calibrates).
    pub vector_pipes: f64,
    /// Cache hierarchy (L1, L2, L3) — empty entries allowed.
    pub caches: Vec<cache::CacheParams>,
    /// DRAM / backing-store access latency in cycles.
    pub mem_latency: u64,
    /// Datapath precision the MAC arrays are built for (area/energy scale).
    pub native_dtype: DType,
}

impl MachineConfig {
    /// Vector lanes for f32 elements.
    pub fn lanes(&self) -> usize {
        self.vlen_bits / 32
    }

    /// Stable identity string for tuning-cache keys: results tuned on one
    /// machine must never be served for another. Covers every knob the
    /// timing model reads, including each cache level's full geometry.
    pub fn fingerprint(&self) -> String {
        let caches: String = self
            .caches
            .iter()
            .map(|c| format!("{}:{}:{}:{};", c.size, c.line, c.assoc, c.latency))
            .collect();
        format!(
            "{}/vlen{}/v{}/pipes{}/iw{}/{}MHz/d{}w{}/[{}]lat{}/{}",
            self.name,
            self.vlen_bits,
            self.has_vector as u8,
            self.vector_pipes,
            self.issue_width,
            self.freq_mhz,
            self.dmem_bytes,
            self.wmem_bytes,
            caches,
            self.mem_latency,
            self.native_dtype.name(),
        )
    }

    /// The XgenSilicon accelerator configuration (our ASIC target):
    /// VLEN=256 RVV, 1 MiB DMEM, 16 MiB WMEM default, 800 MHz, small L1+L2.
    pub fn xgen_asic() -> MachineConfig {
        MachineConfig {
            name: "XgenSilicon ASIC".into(),
            vlen_bits: 256,
            has_vector: true,
            dmem_bytes: 32 << 20,
            wmem_bytes: 1 << 30,
            freq_mhz: 1200.0,
            issue_width: 1.0,
            vector_pipes: 8.0,
            caches: vec![
                cache::CacheParams { name: "L1", size: 32 << 10, line: 64, assoc: 4, latency: 2, energy_pj: 5.0 },
                cache::CacheParams { name: "L2", size: 512 << 10, line: 64, assoc: 8, latency: 12, energy_pj: 25.0 },
            ],
            // DMEM/WMEM are on-chip SRAM (the case study's 30 MB DMEM):
            // the backing store behind L2 is scratchpad, not DRAM.
            mem_latency: 25,
            native_dtype: DType::I8,
        }
    }

    /// The hand-designed ASIC baseline: same process, FP16 datapath, less
    /// memory tuning (bigger, slower SRAMs; no L2 partitioning).
    pub fn hand_asic() -> MachineConfig {
        MachineConfig {
            name: "Hand-designed ASIC".into(),
            vlen_bits: 256,
            has_vector: true,
            dmem_bytes: 32 << 20,
            wmem_bytes: 1 << 30,
            freq_mhz: 600.0,
            issue_width: 1.0,
            vector_pipes: 4.0,
            caches: vec![
                // Conservatively-oversized SRAMs (no cross-stack cost model
                // to size them tightly): more leakage, more pJ per access.
                cache::CacheParams { name: "L1", size: 64 << 10, line: 64, assoc: 2, latency: 3, energy_pj: 9.0 },
                cache::CacheParams { name: "L2", size: 1 << 20, line: 64, assoc: 4, latency: 16, energy_pj: 40.0 },
            ],
            mem_latency: 50,
            native_dtype: DType::F16,
        }
    }

    /// Off-the-shelf CPU baseline (Cortex-A78-like): wide OoO scalar core,
    /// big caches, high clock, FP32 datapath, no custom vector NN path.
    pub fn cpu_a78() -> MachineConfig {
        MachineConfig {
            name: "Off-the-shelf CPU".into(),
            vlen_bits: 128,
            has_vector: false,
            dmem_bytes: 1 << 30,
            wmem_bytes: 1 << 30,
            freq_mhz: 2800.0,
            issue_width: 3.0,
            vector_pipes: 1.0,
            caches: vec![
                cache::CacheParams { name: "L1", size: 64 << 10, line: 64, assoc: 4, latency: 4, energy_pj: 12.0 },
                cache::CacheParams { name: "L2", size: 512 << 10, line: 64, assoc: 8, latency: 14, energy_pj: 40.0 },
                cache::CacheParams { name: "L3", size: 4 << 20, line: 64, assoc: 16, latency: 40, energy_pj: 120.0 },
            ],
            mem_latency: 200,
            native_dtype: DType::F32,
        }
    }
}

/// Address-space layout of the accelerator.
pub mod layout {
    /// DMEM (activations) base address.
    pub const DMEM_BASE: u32 = 0x0000_0000;
    /// WMEM (weights) base address.
    pub const WMEM_BASE: u32 = 0x4000_0000;
    /// Stack top (grows down inside DMEM).
    pub const STACK_TOP: u32 = 0x3FFF_FF00;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_configs_sane() {
        let x = MachineConfig::xgen_asic();
        assert_eq!(x.lanes(), 8);
        assert!(x.has_vector);
        let c = MachineConfig::cpu_a78();
        assert!(!c.has_vector);
        assert!(c.issue_width > 1.0);
        assert_eq!(c.caches.len(), 3);
    }
}
