//! Typed machine traps and a deterministic fault-injection harness.
//!
//! # Trap taxonomy
//!
//! Every way a [`crate::sim::machine::Machine`] can stop abnormally is a
//! [`Trap`]: a structured [`TrapKind`] plus the faulting pc and the
//! *per-run* cycle/instret deltas at the moment of the trap. Traps are
//! surfaced as [`crate::util::Error::Trap`], which callers classify as
//! **machine-scoped**: the machine that raised one is suspect (partial
//! writes, corrupted state) and must be rebuilt from its immutable image
//! before serving again, while the *request* itself may be retried.
//!
//! The fast pre-decoded loop and the naive reference loop must produce
//! bit-identical `Trap` values for the same program — `sim_equiv.rs`
//! asserts this alongside the existing output/stats equivalence.
//!
//! # Fault injection
//!
//! A [`FaultPlan`] is a seeded, sorted schedule of [`Fault`]s that the
//! fast run loop polls once per retired instruction. Supported faults:
//!
//! - **Bit flips** in DMEM/WMEM — `detected: true` models an ECC-style
//!   detected corruption (the run traps immediately with
//!   [`TrapKind::InjectedFault`]); `detected: false` models silent
//!   corruption (the run continues and may produce different bits, which
//!   the harness uses to prove rebuild restores bit-identity).
//! - **Forced illegal-instruction traps** at a chosen retire count.
//! - **Stuck-at register faults** — a register is rewritten with a fixed
//!   value after every retired instruction (silent).
//! - **Instruction-budget overruns** — the remaining budget collapses so
//!   the machine's real `BudgetExceeded` path fires.
//!
//! # Never-wrong-answer invariant
//!
//! Fault injection exists to prove the serving stack's core promise:
//! **a fault may cost a retry or lose a request, but a completed response
//! is always bit-identical to a fault-free serial run.** Detected faults
//! trap (the response is an error, never wrong bits); the only silent
//! faults are the ones the harness injects on purpose to verify that
//! machine rebuild restores bit-identity.

use std::fmt;

use crate::util::rng::Rng;

/// Sentinel pc for traps raised below the run loop (memory helpers) before
/// the loop has a chance to fill in real context.
pub const NO_PC: u32 = u32::MAX;

/// What went wrong.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrapKind {
    /// The fetched word does not decode to any supported instruction.
    IllegalInstruction { word: u32 },
    /// A jump/branch target is not 4-byte aligned.
    MisalignedTarget { target: u32 },
    /// A load/store touched bytes outside the addressed memory region.
    OobAccess {
        region: &'static str,
        addr: u32,
        len: u32,
        store: bool,
    },
    /// The per-run instruction budget was exhausted (runaway kernel).
    BudgetExceeded { budget: u64 },
    /// A vector instruction executed on a scalar-only platform.
    VectorUnsupported,
    /// A detected injected fault (fault-injection harness only).
    InjectedFault { desc: String },
}

impl fmt::Display for TrapKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrapKind::IllegalInstruction { word } => {
                write!(f, "illegal instruction {word:#010x}")
            }
            TrapKind::MisalignedTarget { target } => {
                write!(f, "misaligned branch target {target:#010x}")
            }
            TrapKind::OobAccess {
                region,
                addr,
                len,
                store,
            } => {
                let dir = if *store { "store" } else { "load" };
                write!(f, "{region} OOB {dir} of {len} bytes at {addr:#010x}")
            }
            TrapKind::BudgetExceeded { budget } => {
                write!(f, "instruction budget exceeded ({budget})")
            }
            TrapKind::VectorUnsupported => {
                write!(f, "vector instruction on scalar-only platform")
            }
            TrapKind::InjectedFault { desc } => write!(f, "injected fault: {desc}"),
        }
    }
}

/// A machine trap: what happened, where, and when (per-run deltas).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trap {
    pub kind: TrapKind,
    /// Faulting pc, or [`NO_PC`] if raised below the run loop.
    pub pc: u32,
    /// Cycles elapsed *in this run* when the trap fired.
    pub cycle: u64,
    /// Instructions retired *in this run* when the trap fired.
    pub instret: u64,
}

impl Trap {
    /// A context-free trap; the run loop fills pc/cycle/instret via
    /// `Machine::ctx` before the error escapes.
    pub fn bare(kind: TrapKind) -> Self {
        Trap {
            kind,
            pc: NO_PC,
            cycle: 0,
            instret: 0,
        }
    }
}

impl fmt::Display for Trap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.kind)?;
        if self.pc != NO_PC {
            write!(
                f,
                " at pc {:#010x} (cycle {}, instret {})",
                self.pc, self.cycle, self.instret
            )?;
        }
        Ok(())
    }
}

/// A single injectable hardware fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip one bit of a byte in machine memory (DMEM or WMEM by address).
    /// `detected: true` traps immediately (ECC detection); `false` is
    /// silent corruption.
    BitFlip { addr: u32, bit: u8, detected: bool },
    /// Force an illegal-instruction-style trap.
    IllegalTrap,
    /// From this point on, register `reg` reads back `value` after every
    /// retired instruction (silent; x0 is exempt).
    StuckReg { reg: u8, value: i32 },
    /// Collapse the remaining instruction budget so the machine's real
    /// budget-exceeded path fires on the next fetch.
    BudgetOverrun,
}

/// A fault scheduled at a retire count within one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// Fires when this many instructions have retired in the current run.
    pub at_instret: u64,
    pub kind: FaultKind,
}

/// A deterministic, sorted schedule of faults for a single run.
///
/// The fast run loop polls the plan once per retired instruction; the plan
/// is consumed by the run (one-shot) and its injection count is folded
/// into `RunStats::faults_injected`. The reference loop never injects
/// faults — it is the oracle the harness compares against.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    next: usize,
    injected: u64,
}

impl FaultPlan {
    /// Build a plan from an arbitrary set of faults (sorted internally).
    pub fn new(mut faults: Vec<Fault>) -> Self {
        faults.sort_by_key(|f| f.at_instret);
        FaultPlan {
            faults,
            next: 0,
            injected: 0,
        }
    }

    /// A seeded single-fault chaos plan: one *detected* fault (bit flip,
    /// forced illegal trap, or budget overrun) at a pseudorandom retire
    /// count. Detected-only so chaos serving can never silently corrupt
    /// an answer — that is the harness's never-wrong-answer invariant.
    /// The retire count is kept small so the fault lands inside even a
    /// short inference run (a plan scheduled past the end of the program
    /// simply never fires, which reads as a fault-free request).
    pub fn chaos(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA17_FA17_AB1E);
        let at_instret = 1 + rng.index(200) as u64;
        let kind = match rng.index(3) {
            0 => FaultKind::BitFlip {
                // Low DMEM addresses exist on every platform config.
                addr: rng.index(4096) as u32,
                bit: (rng.index(8)) as u8,
                detected: true,
            },
            1 => FaultKind::IllegalTrap,
            _ => FaultKind::BudgetOverrun,
        };
        FaultPlan::new(vec![Fault { at_instret, kind }])
    }

    /// The next fault due at or before `retired` instructions, if any.
    /// Advances the schedule and counts the injection.
    pub fn next_due(&mut self, retired: u64) -> Option<FaultKind> {
        let f = self.faults.get(self.next)?;
        if f.at_instret <= retired {
            self.next += 1;
            self.injected += 1;
            Some(f.kind)
        } else {
            None
        }
    }

    /// Faults injected so far by this plan.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Total faults scheduled.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// True when no faults are scheduled.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fires_in_retire_order() {
        let mut plan = FaultPlan::new(vec![
            Fault {
                at_instret: 30,
                kind: FaultKind::IllegalTrap,
            },
            Fault {
                at_instret: 10,
                kind: FaultKind::BudgetOverrun,
            },
        ]);
        assert_eq!(plan.next_due(5), None);
        assert_eq!(plan.next_due(10), Some(FaultKind::BudgetOverrun));
        assert_eq!(plan.next_due(10), None);
        assert_eq!(plan.next_due(31), Some(FaultKind::IllegalTrap));
        assert_eq!(plan.next_due(1000), None);
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn chaos_plan_is_deterministic_and_detected() {
        let a = FaultPlan::chaos(7);
        let b = FaultPlan::chaos(7);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.len(), 1);
        // Chaos plans must never schedule silent corruption.
        match a.faults[0].kind {
            FaultKind::BitFlip { detected, .. } => assert!(detected),
            FaultKind::IllegalTrap | FaultKind::BudgetOverrun => {}
            FaultKind::StuckReg { .. } => panic!("chaos scheduled a silent fault"),
        }
    }

    #[test]
    fn trap_display_keeps_legacy_substrings() {
        let t = Trap {
            kind: TrapKind::BudgetExceeded { budget: 1000 },
            pc: 0x40,
            cycle: 12,
            instret: 1001,
        };
        let s = t.to_string();
        assert!(s.contains("budget"), "{s}");
        assert!(s.contains("pc 0x00000040"), "{s}");

        let m = Trap::bare(TrapKind::MisalignedTarget { target: 0x1232 });
        assert!(m.to_string().contains("misaligned"), "{m}");
        // Bare traps print no pc context.
        assert!(!m.to_string().contains("pc"), "{m}");
    }
}
