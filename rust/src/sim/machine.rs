//! Functional RV32I+RVV machine: runs *encoded* binaries with cycle and
//! cache accounting.
//!
//! This is the hardware-in-the-loop stand-in: generated kernels actually run
//! here, numerics are compared against the IR executor, and the cycle
//! counts are the "measurements" the learned cost model trains on (small
//! kernels; the analytic `timing` model extrapolates for big ones and is
//! cross-validated against this machine).
//!
//! Execution has two paths:
//!
//! * **Fast path** (the default): [`Machine::run`] predecodes the binary
//!   once ([`crate::sim::predecode`]) and drives [`Machine::run_predecoded`],
//!   a tight index-based dispatch loop — no per-instruction decode, fixed
//!   `[u64; OpClass::COUNT]` class counters, a flat contiguous vector
//!   register file, and one bounds check per memory access through a
//!   unified DMEM/WMEM view. `run` is a compatibility wrapper: same
//!   signature, same semantics, same [`RunStats`].
//! * **Reference path**: [`Machine::run_reference`] is the naive
//!   decode-per-step loop (fetch → `decode::decode` → execute, `BTreeMap`
//!   class bumps, per-element vector memory). It exists as the golden
//!   baseline: `rust/tests/sim_equiv.rs` proves both paths agree
//!   bit-for-bit on numerics and exactly on cycles/instret/class counts/
//!   cache stats, and `benches/bench_sim_wallclock.rs` tracks the speedup.

use std::collections::BTreeMap;

use crate::isa::{decode, regs, Op, OpClass};
use crate::sim::cache::Hierarchy;
use crate::sim::fault::{FaultKind, FaultPlan, Trap, TrapKind, NO_PC};
use crate::sim::predecode::{self, MicroOp, Predecoded, Slot};
use crate::sim::{layout, MachineConfig};
use crate::util::error::{Error, Result};

/// Execution summary.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunStats {
    pub cycles: u64,
    pub instret: u64,
    pub class_counts: BTreeMap<&'static str, u64>,
    /// Faults delivered by an armed [`FaultPlan`] during this run (always 0
    /// on the reference path and on fault-free runs).
    pub faults_injected: u64,
}

/// Where execution goes after one step.
enum Ctl {
    Next,
    Jump(usize),
}

/// The simulated machine.
pub struct Machine {
    pub cfg: MachineConfig,
    pub x: [i32; 32],
    pub f: [f32; 32],
    /// Vector register file, flat: register `i` occupies
    /// `v[i * lanes .. (i + 1) * lanes]` — LMUL groups are contiguous.
    v: Vec<f32>,
    lanes: usize,
    /// Active vector length (elements) and register-group multiplier.
    pub vl: usize,
    pub lmul: usize,
    dmem: Vec<u8>,
    wmem: Vec<u8>,
    pub cycles: u64,
    pub instret: u64,
    pub hier: Hierarchy,
    class_counts: [u64; OpClass::COUNT],
    /// Instruction budget guard against runaway programs.
    pub max_instret: u64,
    /// Issue-width-scaled cycle cost for 1- and 2-cycle Alu/Branch/Jump ops
    /// (precomputed so the hot loop never touches floating point).
    issue_scaled: [u64; 3],
    /// One-shot fault schedule consumed by the next [`Self::run_predecoded`]
    /// (the reference loop never injects — it is the fault-free oracle).
    fault: Option<FaultPlan>,
}

#[cold]
fn oob(region: &'static str, addr: u32, len: usize, store: bool) -> Error {
    Error::Trap(Trap::bare(TrapKind::OobAccess {
        region,
        addr,
        len: len as u32,
        store,
    }))
}

#[cold]
fn scalar_only() -> Error {
    Error::Trap(Trap::bare(TrapKind::VectorUnsupported))
}

/// Unified DMEM/WMEM read view: one region branch, one bounds check.
/// Free functions (not methods) so vector ops can hold a memory view and a
/// mutable vector-register slice at the same time (disjoint field borrows).
#[inline]
fn view<'a>(dmem: &'a [u8], wmem: &'a [u8], addr: u32, len: usize) -> Result<&'a [u8]> {
    if addr >= layout::WMEM_BASE {
        let off = (addr - layout::WMEM_BASE) as usize;
        wmem.get(off..off + len)
            .ok_or_else(|| oob("WMEM", addr, len, false))
    } else {
        let off = addr as usize;
        dmem.get(off..off + len)
            .ok_or_else(|| oob("DMEM", addr, len, false))
    }
}

/// Mutable counterpart of [`view`].
#[inline]
fn view_mut<'a>(
    dmem: &'a mut [u8],
    wmem: &'a mut [u8],
    addr: u32,
    len: usize,
) -> Result<&'a mut [u8]> {
    if addr >= layout::WMEM_BASE {
        let off = (addr - layout::WMEM_BASE) as usize;
        wmem.get_mut(off..off + len)
            .ok_or_else(|| oob("WMEM", addr, len, true))
    } else {
        let off = addr as usize;
        dmem.get_mut(off..off + len)
            .ok_or_else(|| oob("DMEM", addr, len, true))
    }
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Machine {
        let lanes = cfg.lanes();
        let hier = Hierarchy::new(&cfg.caches, cfg.mem_latency);
        // Cap host allocation: the address map allows huge DMEM/WMEM but the
        // tests only touch the low megabytes.
        let dmem = vec![0u8; cfg.dmem_bytes.min(64 << 20)];
        let wmem = vec![0u8; cfg.wmem_bytes.min(64 << 20)];
        let mut x = [0; 32];
        // ABI: stack pointer starts at DMEM top (grows down).
        x[regs::SP as usize] = dmem.len() as i32;
        let iw = cfg.issue_width;
        let issue_scaled = [
            1,
            ((1.0_f64 / iw).ceil() as u64).max(1),
            ((2.0_f64 / iw).ceil() as u64).max(1),
        ];
        Machine {
            cfg,
            x,
            f: [0.0; 32],
            v: vec![0.0; 32 * lanes],
            lanes,
            vl: lanes,
            lmul: 1,
            dmem,
            wmem,
            cycles: 0,
            instret: 0,
            hier,
            class_counts: [0; OpClass::COUNT],
            max_instret: 500_000_000,
            issue_scaled,
            fault: None,
        }
    }

    /// Arm a one-shot fault schedule: the next [`Self::run_predecoded`]
    /// consumes it (injections are counted in `RunStats::faults_injected`
    /// when the run completes). A full reset does not disarm it, so a plan
    /// armed before `LoadedModel::infer` survives the pre-run reset.
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.fault = Some(plan);
    }

    /// Reset architectural state for a fresh run while keeping WMEM — the
    /// re-stage path for long-lived serving machines: weights staged once
    /// persist, everything a program can observe or that affects timing goes
    /// back to power-on state. The first `dmem_zero_extent` bytes of DMEM
    /// are zeroed (clamped to the DMEM size; pass the memory plan's
    /// `dmem_peak` to avoid re-zeroing untouched megabytes, or `usize::MAX`
    /// when the program's footprint is unknown). Registers, vector state,
    /// cycle/instret counters, per-class counts, and the full cache
    /// hierarchy (tags + LRU, not just counters) reset so a subsequent
    /// [`Self::run_predecoded`] is bit-identical — outputs *and* stats — to
    /// a run on a freshly constructed machine with the same WMEM contents.
    /// `max_instret` is configuration, not run state: it persists.
    pub fn reset_keep_wmem(&mut self, dmem_zero_extent: usize) {
        let n = dmem_zero_extent.min(self.dmem.len());
        self.dmem[..n].fill(0);
        self.x = [0; 32];
        self.x[regs::SP as usize] = self.dmem.len() as i32;
        self.f = [0.0; 32];
        self.v.fill(0.0);
        self.vl = self.lanes;
        self.lmul = 1;
        self.cycles = 0;
        self.instret = 0;
        self.class_counts = [0; OpClass::COUNT];
        self.hier.reset();
    }

    // -- memory ------------------------------------------------------------

    /// Read-only view of `len` bytes at `addr` (single bounds check).
    pub fn mem_ref(&self, addr: u32, len: usize) -> Result<&[u8]> {
        view(&self.dmem, &self.wmem, addr, len)
    }

    /// Mutable view of `len` bytes at `addr` (single bounds check).
    pub fn mem_mut(&mut self, addr: u32, len: usize) -> Result<&mut [u8]> {
        view_mut(&mut self.dmem, &mut self.wmem, addr, len)
    }

    pub fn load_u32(&self, addr: u32) -> Result<u32> {
        let b = self.mem_ref(addr, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn store_u32(&mut self, addr: u32, val: u32) -> Result<()> {
        self.mem_mut(addr, 4)?.copy_from_slice(&val.to_le_bytes());
        Ok(())
    }

    pub fn load_f32(&self, addr: u32) -> Result<f32> {
        Ok(f32::from_bits(self.load_u32(addr)?))
    }

    pub fn store_f32(&mut self, addr: u32, val: f32) -> Result<()> {
        self.store_u32(addr, val.to_bits())
    }

    /// Bulk staging: one address-map resolve + bounds check for the whole
    /// tensor, then a straight byte copy (used by `runtime::simrun` to
    /// stage weights/inputs and read outputs back).
    pub fn write_f32_slice(&mut self, addr: u32, vals: &[f32]) -> Result<()> {
        let dst = self.mem_mut(addr, vals.len() * 4)?;
        for (c, v) in dst.chunks_exact_mut(4).zip(vals) {
            c.copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn read_f32_slice(&self, addr: u32, n: usize) -> Result<Vec<f32>> {
        let src = self.mem_ref(addr, n * 4)?;
        Ok(src
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn write_u32_slice(&mut self, addr: u32, vals: &[u32]) -> Result<()> {
        let dst = self.mem_mut(addr, vals.len() * 4)?;
        for (c, v) in dst.chunks_exact_mut(4).zip(vals) {
            c.copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn write_i8_slice(&mut self, addr: u32, vals: &[i8]) -> Result<()> {
        let dst = self.mem_mut(addr, vals.len())?;
        for (d, &v) in dst.iter_mut().zip(vals) {
            *d = v as u8;
        }
        Ok(())
    }

    // -- accounting ---------------------------------------------------------

    /// Bump for issue-width-scaled classes (Alu/Branch/Jump), `c` ∈ {1, 2}.
    #[inline(always)]
    fn bump_issue(&mut self, class: OpClass, c: usize) {
        self.class_counts[class.index()] += 1;
        self.cycles += self.issue_scaled[c];
    }

    /// Bump for everything else: cycles charged as given (min 1).
    #[inline(always)]
    fn bump_raw(&mut self, class: OpClass, cycles: u64) {
        self.class_counts[class.index()] += 1;
        self.cycles += cycles.max(1);
    }

    #[inline(always)]
    fn wx(&mut self, rd: usize, val: u32) {
        if rd != regs::ZERO as usize {
            self.x[rd] = val as i32;
        }
    }

    #[inline(always)]
    fn wxi(&mut self, rd: usize, val: i32) {
        if rd != regs::ZERO as usize {
            self.x[rd] = val;
        }
    }

    /// Stats of the run that started at the given counter snapshots —
    /// everything, including class counts, is a per-run delta.
    fn stats_since(
        &self,
        start_cycles: u64,
        start_instret: u64,
        start_counts: &[u64; OpClass::COUNT],
    ) -> RunStats {
        RunStats {
            cycles: self.cycles - start_cycles,
            instret: self.instret - start_instret,
            class_counts: OpClass::ALL
                .iter()
                .map(|c| (c.name(), self.class_counts[c.index()] - start_counts[c.index()]))
                .filter(|(_, n)| *n > 0)
                .collect(),
            faults_injected: 0,
        }
    }

    /// A trap with full context: faulting pc plus the per-run cycle/instret
    /// deltas *at this moment* (the run-loop counters have already been
    /// bumped exactly as far as the reference loop would have).
    #[cold]
    fn trap_here(
        &self,
        kind: TrapKind,
        pc: u32,
        start_cycles: u64,
        start_instret: u64,
    ) -> Error {
        Error::Trap(Trap {
            kind,
            pc,
            cycle: self.cycles - start_cycles,
            instret: self.instret - start_instret,
        })
    }

    /// Fill pc/cycle/instret into a context-free trap raised below the run
    /// loop (memory helpers, `step`); errors that already carry context —
    /// or are not traps at all — pass through untouched.
    #[cold]
    fn ctx(&self, e: Error, pc: u32, start_cycles: u64, start_instret: u64) -> Error {
        match e {
            Error::Trap(t) if t.pc == NO_PC => {
                self.trap_here(t.kind, pc, start_cycles, start_instret)
            }
            other => other,
        }
    }

    #[cold]
    fn budget_exceeded(&self, pc: u32, start_cycles: u64, start_instret: u64) -> Error {
        self.trap_here(
            TrapKind::BudgetExceeded {
                budget: self.max_instret,
            },
            pc,
            start_cycles,
            start_instret,
        )
    }

    // -- execution: fast path ----------------------------------------------

    /// Execute an encoded program until it falls off the end.
    /// Returns run statistics; machine state persists for inspection.
    ///
    /// Compatibility wrapper: predecodes once, then runs the fast dispatch
    /// loop ([`Self::run_predecoded`]). Identical observable behavior to
    /// the historical decode-per-step loop (kept as
    /// [`Self::run_reference`]).
    pub fn run(&mut self, prog: &[u32]) -> Result<RunStats> {
        let p = predecode::predecode(prog);
        self.run_predecoded(&p)
    }

    /// The fast path: drive a predecoded program through the index-based
    /// dispatch loop. Callers that run the same binary many times can
    /// predecode once and amortize even the single decode pass.
    pub fn run_predecoded(&mut self, p: &Predecoded) -> Result<RunStats> {
        let start_instret = self.instret;
        let start_cycles = self.cycles;
        let start_counts = self.class_counts;
        // Fault harness state: the armed plan is consumed by this run; a
        // BudgetOverrun fault collapses the *local* budget so the machine's
        // real budget-exceeded path fires; a StuckReg fault pins a register
        // after every retired instruction from then on.
        let mut plan = self.fault.take();
        let mut budget = self.max_instret;
        let mut stuck: Option<(usize, i32)> = None;
        let n = p.len();
        let mut idx = 0usize;
        while idx < n {
            let pc = (idx * 4) as u32;
            let retired = self.instret - start_instret;
            if retired > budget {
                return Err(self.budget_exceeded(pc, start_cycles, start_instret));
            }
            if let Some(pl) = plan.as_mut() {
                while let Some(k) = pl.next_due(retired) {
                    match k {
                        FaultKind::BitFlip {
                            addr,
                            bit,
                            detected,
                        } => {
                            if let Ok(b) = self.mem_mut(addr, 1) {
                                b[0] ^= 1 << (bit & 7);
                            }
                            if detected {
                                return Err(self.trap_here(
                                    TrapKind::InjectedFault {
                                        desc: format!(
                                            "detected bit flip (bit {} at {addr:#010x})",
                                            bit & 7
                                        ),
                                    },
                                    pc,
                                    start_cycles,
                                    start_instret,
                                ));
                            }
                        }
                        FaultKind::IllegalTrap => {
                            return Err(self.trap_here(
                                TrapKind::InjectedFault {
                                    desc: "forced illegal-instruction trap".into(),
                                },
                                pc,
                                start_cycles,
                                start_instret,
                            ));
                        }
                        FaultKind::StuckReg { reg, value } => {
                            stuck = Some(((reg as usize & 31).max(1), value));
                        }
                        FaultKind::BudgetOverrun => budget = retired,
                    }
                }
            }
            match &p.slots[idx] {
                Slot::Op(u) => {
                    self.instret += 1;
                    let ctl = match self.step(u) {
                        Ok(c) => c,
                        Err(e) => return Err(self.ctx(e, pc, start_cycles, start_instret)),
                    };
                    if let Some((r, v)) = stuck {
                        self.x[r] = v;
                    }
                    idx = match ctl {
                        Ctl::Next => idx + 1,
                        Ctl::Jump(t) => t,
                    };
                }
                Slot::Illegal(w) => {
                    // Executing an undecodable word faults before retiring —
                    // same machine state as the reference loop's decode
                    // failure (no instret bump).
                    return Err(self.trap_here(
                        TrapKind::IllegalInstruction { word: *w },
                        pc,
                        start_cycles,
                        start_instret,
                    ));
                }
                Slot::Misaligned(t) => {
                    // The word decoded fine — the reference loop retires its
                    // instret bump before faulting, so match that state.
                    self.instret += 1;
                    return Err(self.trap_here(
                        TrapKind::MisalignedTarget { target: *t },
                        pc,
                        start_cycles,
                        start_instret,
                    ));
                }
            }
        }
        let mut stats = self.stats_since(start_cycles, start_instret, &start_counts);
        stats.faults_injected = plan.map(|pl| pl.injected()).unwrap_or(0);
        Ok(stats)
    }

    /// Execute one resolved micro-op.
    #[inline(always)]
    fn step(&mut self, u: &MicroOp) -> Result<Ctl> {
        use Op::*;
        match u.op {
            // -- scalar integer ------------------------------------------
            Lui => {
                self.wx(u.rd, u.aux);
                self.bump_issue(OpClass::Alu, 1);
            }
            Auipc => {
                self.wx(u.rd, u.aux);
                self.bump_issue(OpClass::Alu, 1);
            }
            Jal => {
                self.wx(u.rd, u.aux);
                self.bump_issue(OpClass::Jump, 1);
                return Ok(Ctl::Jump(u.target));
            }
            Jalr => {
                let t = (self.x[u.rs1] as u32).wrapping_add(u.imm as u32) & !1;
                self.wx(u.rd, u.aux);
                self.bump_issue(OpClass::Jump, 1);
                if t % 4 != 0 {
                    return Err(Error::Trap(Trap::bare(TrapKind::MisalignedTarget {
                        target: t,
                    })));
                }
                return Ok(Ctl::Jump((t / 4) as usize));
            }
            Beq | Bne | Blt | Bge => {
                let a = self.x[u.rs1];
                let b = self.x[u.rs2];
                let taken = match u.op {
                    Beq => a == b,
                    Bne => a != b,
                    Blt => a < b,
                    _ => a >= b,
                };
                if taken {
                    if u.target == predecode::MISALIGNED_TARGET {
                        return Err(Error::Trap(Trap::bare(TrapKind::MisalignedTarget {
                            target: u.aux,
                        })));
                    }
                    self.bump_issue(OpClass::Branch, 2); // taken-branch penalty
                    return Ok(Ctl::Jump(u.target));
                }
                self.bump_issue(OpClass::Branch, 1);
            }
            Lw => {
                let addr = (self.x[u.rs1] as u32).wrapping_add(u.imm as u32);
                let lat = self.hier.access(addr as u64);
                let val = self.load_u32(addr)?;
                self.wx(u.rd, val);
                self.bump_raw(OpClass::Load, lat);
            }
            Sw => {
                let addr = (self.x[u.rs1] as u32).wrapping_add(u.imm as u32);
                let lat = self.hier.access(addr as u64);
                self.store_u32(addr, self.x[u.rs2] as u32)?;
                self.bump_raw(OpClass::Store, lat.min(2)); // store buffer hides latency
            }
            Addi => { self.wxi(u.rd, self.x[u.rs1].wrapping_add(u.imm)); self.bump_issue(OpClass::Alu, 1); }
            Slti => { self.wxi(u.rd, (self.x[u.rs1] < u.imm) as i32); self.bump_issue(OpClass::Alu, 1); }
            Andi => { self.wxi(u.rd, self.x[u.rs1] & u.imm); self.bump_issue(OpClass::Alu, 1); }
            Ori => { self.wxi(u.rd, self.x[u.rs1] | u.imm); self.bump_issue(OpClass::Alu, 1); }
            Xori => { self.wxi(u.rd, self.x[u.rs1] ^ u.imm); self.bump_issue(OpClass::Alu, 1); }
            Slli => { self.wxi(u.rd, ((self.x[u.rs1] as u32) << u.imm) as i32); self.bump_issue(OpClass::Alu, 1); }
            Srli => { self.wxi(u.rd, ((self.x[u.rs1] as u32) >> u.imm) as i32); self.bump_issue(OpClass::Alu, 1); }
            Srai => { self.wxi(u.rd, self.x[u.rs1] >> u.imm); self.bump_issue(OpClass::Alu, 1); }
            Add => { self.wxi(u.rd, self.x[u.rs1].wrapping_add(self.x[u.rs2])); self.bump_issue(OpClass::Alu, 1); }
            Sub => { self.wxi(u.rd, self.x[u.rs1].wrapping_sub(self.x[u.rs2])); self.bump_issue(OpClass::Alu, 1); }
            Sll => { self.wxi(u.rd, ((self.x[u.rs1] as u32) << (self.x[u.rs2] & 31)) as i32); self.bump_issue(OpClass::Alu, 1); }
            Srl => { self.wxi(u.rd, ((self.x[u.rs1] as u32) >> (self.x[u.rs2] & 31)) as i32); self.bump_issue(OpClass::Alu, 1); }
            Sra => { self.wxi(u.rd, self.x[u.rs1] >> (self.x[u.rs2] & 31)); self.bump_issue(OpClass::Alu, 1); }
            And => { self.wxi(u.rd, self.x[u.rs1] & self.x[u.rs2]); self.bump_issue(OpClass::Alu, 1); }
            Or => { self.wxi(u.rd, self.x[u.rs1] | self.x[u.rs2]); self.bump_issue(OpClass::Alu, 1); }
            Xor => { self.wxi(u.rd, self.x[u.rs1] ^ self.x[u.rs2]); self.bump_issue(OpClass::Alu, 1); }
            Slt => { self.wxi(u.rd, (self.x[u.rs1] < self.x[u.rs2]) as i32); self.bump_issue(OpClass::Alu, 1); }
            Mul => { self.wxi(u.rd, self.x[u.rs1].wrapping_mul(self.x[u.rs2])); self.bump_raw(OpClass::Mul, 3); }
            Mulh => {
                let p = (self.x[u.rs1] as i64) * (self.x[u.rs2] as i64);
                self.wxi(u.rd, (p >> 32) as i32);
                self.bump_raw(OpClass::Mul, 3);
            }
            Div => {
                let d = self.x[u.rs2];
                self.wxi(u.rd, if d == 0 { -1 } else { self.x[u.rs1].wrapping_div(d) });
                self.bump_raw(OpClass::Div, 20);
            }
            Rem => {
                let d = self.x[u.rs2];
                self.wxi(u.rd, if d == 0 { self.x[u.rs1] } else { self.x[u.rs1].wrapping_rem(d) });
                self.bump_raw(OpClass::Div, 20);
            }

            // -- scalar float --------------------------------------------
            Flw => {
                let addr = (self.x[u.rs1] as u32).wrapping_add(u.imm as u32);
                let lat = self.hier.access(addr as u64);
                self.f[u.rd] = self.load_f32(addr)?;
                self.bump_raw(OpClass::Load, lat);
            }
            Fsw => {
                let addr = (self.x[u.rs1] as u32).wrapping_add(u.imm as u32);
                let lat = self.hier.access(addr as u64);
                self.store_f32(addr, self.f[u.rs2])?;
                self.bump_raw(OpClass::Store, lat.min(2));
            }
            FaddS => { self.f[u.rd] = self.f[u.rs1] + self.f[u.rs2]; self.bump_raw(OpClass::FAlu, 2); }
            FsubS => { self.f[u.rd] = self.f[u.rs1] - self.f[u.rs2]; self.bump_raw(OpClass::FAlu, 2); }
            FmulS => { self.f[u.rd] = self.f[u.rs1] * self.f[u.rs2]; self.bump_raw(OpClass::FMul, 3); }
            FdivS => { self.f[u.rd] = self.f[u.rs1] / self.f[u.rs2]; self.bump_raw(OpClass::FDiv, 16); }
            FmaddS => {
                self.f[u.rd] = self.f[u.rs1] * self.f[u.rs2] + self.f[u.rs3];
                self.bump_raw(OpClass::FMa, 4);
            }
            FminS => { self.f[u.rd] = self.f[u.rs1].min(self.f[u.rs2]); self.bump_raw(OpClass::FAlu, 2); }
            FmaxS => { self.f[u.rd] = self.f[u.rs1].max(self.f[u.rs2]); self.bump_raw(OpClass::FAlu, 2); }
            FcvtWS => { self.wxi(u.rd, self.f[u.rs1] as i32); self.bump_raw(OpClass::FAlu, 2); }
            FcvtSW => { self.f[u.rd] = self.x[u.rs1] as f32; self.bump_raw(OpClass::FAlu, 2); }
            FexpS => { self.f[u.rd] = self.f[u.rs1].exp(); self.bump_raw(OpClass::FCustom, 8); }
            FrsqrtS => { self.f[u.rd] = 1.0 / self.f[u.rs1].sqrt(); self.bump_raw(OpClass::FCustom, 8); }

            // -- vector ---------------------------------------------------
            Vsetvli => {
                if !self.cfg.has_vector {
                    return Err(scalar_only());
                }
                self.lmul = 1 << u.rs3;
                let vlmax = self.lanes * self.lmul;
                let avl = self.x[u.rs1].max(0) as usize;
                self.vl = avl.min(vlmax);
                self.wxi(u.rd, self.vl as i32);
                self.bump_raw(OpClass::VSet, 1);
            }
            Vle32 | Vle8 | Vse32 | Vse8 => {
                if !self.cfg.has_vector {
                    return Err(scalar_only());
                }
                let base = self.x[u.rs1] as u32;
                let esz: usize = if matches!(u.op, Vle32 | Vse32) { 4 } else { 1 };
                // One cache access per line touched.
                let bytes = self.vl * esz;
                let mut lat = 0;
                let mut a = base as u64;
                let span_end = base as u64 + bytes as u64;
                while a < span_end {
                    lat = lat.max(self.hier.access(a));
                    a += 64;
                }
                let vl = self.vl;
                let vbase = u.rd * self.lanes;
                // Routing the whole span by its base address is safe: the
                // DMEM allocation is capped strictly below WMEM_BASE, so a
                // span can never run contiguously from DMEM into WMEM — any
                // region-crossing span passes through the unmapped hole and
                // faults here exactly as the per-element reference loop does.
                if bytes > 0 {
                    match u.op {
                        Vle32 => {
                            let src = view(&self.dmem, &self.wmem, base, bytes)?;
                            for (d, c) in self.v[vbase..vbase + vl]
                                .iter_mut()
                                .zip(src.chunks_exact(4))
                            {
                                *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                            }
                        }
                        Vse32 => {
                            let dst = view_mut(&mut self.dmem, &mut self.wmem, base, bytes)?;
                            for (c, s) in dst
                                .chunks_exact_mut(4)
                                .zip(&self.v[vbase..vbase + vl])
                            {
                                c.copy_from_slice(&s.to_le_bytes());
                            }
                        }
                        Vle8 => {
                            let src = view(&self.dmem, &self.wmem, base, bytes)?;
                            for (d, &b) in
                                self.v[vbase..vbase + vl].iter_mut().zip(src)
                            {
                                *d = b as i8 as f32;
                            }
                        }
                        _ => {
                            let dst = view_mut(&mut self.dmem, &mut self.wmem, base, bytes)?;
                            for (c, s) in
                                dst.iter_mut().zip(&self.v[vbase..vbase + vl])
                            {
                                *c = (*s as i32).clamp(-128, 127) as u8;
                            }
                        }
                    }
                }
                let class = if matches!(u.op, Vle32 | Vle8) { OpClass::VLoad } else { OpClass::VStore };
                // Throughput: lanes per cycle per port + miss latency.
                self.bump_raw(class, (vl as u64 / 4).max(1) + lat);
            }
            VaddVV | VfaddVV => self.vbin(u, |a, b| a + b),
            VsubVV | VfsubVV => self.vbin(u, |a, b| a - b),
            VmulVV | VfmulVV => self.vmul(u),
            VmaccVV | VfmaccVV => self.vfma(u),
            VfmaccVF => {
                let s = self.f[u.rs1];
                let (d, b) = (u.rd * self.lanes, u.rs2 * self.lanes);
                for e in 0..self.vl {
                    let acc = self.v[d + e] + s * self.v[b + e];
                    self.v[d + e] = acc;
                }
                self.bump_raw(OpClass::VFma, (2 * self.lmul) as u64);
            }
            VfredsumVS => {
                let (d, a, b) = (u.rd * self.lanes, u.rs1 * self.lanes, u.rs2 * self.lanes);
                let mut acc = self.v[a];
                for e in 0..self.vl {
                    acc += self.v[b + e];
                }
                self.v[d] = acc;
                self.bump_raw(OpClass::VRed, 4 + self.lmul as u64);
            }
            VfmaxVV => self.vbin(u, |a, b| a.max(b)),
            VfmvVF => {
                let s = self.f[u.rs1];
                let d = u.rd * self.lanes;
                for e in 0..self.vl {
                    self.v[d + e] = s;
                }
                self.bump_raw(OpClass::VAlu, self.lmul as u64);
            }
        }
        Ok(Ctl::Next)
    }

    #[inline(always)]
    fn vbin(&mut self, u: &MicroOp, f: impl Fn(f32, f32) -> f32) {
        let (d, a, b) = (u.rd * self.lanes, u.rs1 * self.lanes, u.rs2 * self.lanes);
        for e in 0..self.vl {
            self.v[d + e] = f(self.v[a + e], self.v[b + e]);
        }
        self.bump_raw(OpClass::VAlu, self.lmul as u64);
    }

    #[inline(always)]
    fn vmul(&mut self, u: &MicroOp) {
        let (d, a, b) = (u.rd * self.lanes, u.rs1 * self.lanes, u.rs2 * self.lanes);
        for e in 0..self.vl {
            self.v[d + e] = self.v[a + e] * self.v[b + e];
        }
        self.bump_raw(OpClass::VMul, (2 * self.lmul) as u64);
    }

    #[inline(always)]
    fn vfma(&mut self, u: &MicroOp) {
        // vmacc vd, vs1, vs2: vd += vs1 * vs2
        let (d, a, b) = (u.rd * self.lanes, u.rs1 * self.lanes, u.rs2 * self.lanes);
        for e in 0..self.vl {
            let acc = self.v[d + e] + self.v[a + e] * self.v[b + e];
            self.v[d + e] = acc;
        }
        self.bump_raw(OpClass::VFma, (2 * self.lmul) as u64);
    }

    // -- execution: naive reference loop -------------------------------------

    /// Element `elem` of vector register group `base` through the naive
    /// per-element index math of the historical interpreter.
    fn vreg_ref(&self, base: usize, elem: usize) -> f32 {
        self.v[(base + elem / self.lanes) * self.lanes + elem % self.lanes]
    }

    fn vreg_set_ref(&mut self, base: usize, elem: usize, val: f32) {
        self.v[(base + elem / self.lanes) * self.lanes + elem % self.lanes] = val;
    }

    /// Naive per-instruction bump: `BTreeMap` entry walk + floating-point
    /// issue-width scaling, exactly as the historical loop did it.
    fn bump_ref(&mut self, counts: &mut BTreeMap<OpClass, u64>, class: OpClass, cycles: u64) {
        *counts.entry(class).or_insert(0) += 1;
        // Superscalar baselines retire multiple scalar ops per cycle.
        let scaled = if matches!(class, OpClass::Alu | OpClass::Branch | OpClass::Jump) {
            ((cycles as f64) / self.cfg.issue_width).ceil() as u64
        } else {
            cycles
        };
        self.cycles += scaled.max(1);
    }

    /// The naive decode-per-step loop: fetch a word, run `decode::decode`,
    /// execute, repeat. This is the golden reference the fast path is
    /// differentially tested against (`rust/tests/sim_equiv.rs`) and the
    /// baseline `benches/bench_sim_wallclock.rs` measures speedup over.
    /// On success its observable state (registers, memory, cycles, instret,
    /// class counts, cache stats) is bit-identical to [`Self::run`]'s; on
    /// error the class counters of the partial run are dropped.
    pub fn run_reference(&mut self, prog: &[u32]) -> Result<RunStats> {
        let start_instret = self.instret;
        let start_cycles = self.cycles;
        let start_counts = self.class_counts;
        let mut counts: BTreeMap<OpClass, u64> = BTreeMap::new();
        let end = (prog.len() * 4) as u32;
        let mut pc: u32 = 0;
        while pc < end {
            if self.instret - start_instret > self.max_instret {
                return Err(self.budget_exceeded(pc, start_cycles, start_instret));
            }
            let word = prog[(pc / 4) as usize];
            let i = match decode::decode(word) {
                Ok(i) => i,
                Err(_) => {
                    return Err(self.trap_here(
                        TrapKind::IllegalInstruction { word },
                        pc,
                        start_cycles,
                        start_instret,
                    ))
                }
            };
            self.instret += 1;
            let mut next = pc.wrapping_add(4);
            let (rd, rs1, rs2, rs3) =
                (i.rd as usize, i.rs1 as usize, i.rs2 as usize, i.rs3 as usize);
            use Op::*;
            match i.op {
                Lui => {
                    self.wx(rd, (i.imm as u32) << 12);
                    self.bump_ref(&mut counts, OpClass::Alu, 1);
                }
                Auipc => {
                    self.wx(rd, pc.wrapping_add((i.imm as u32) << 12));
                    self.bump_ref(&mut counts, OpClass::Alu, 1);
                }
                Jal => {
                    let t = pc.wrapping_add(i.imm as u32);
                    if t % 4 != 0 {
                        return Err(self.trap_here(
                            TrapKind::MisalignedTarget { target: t },
                            pc,
                            start_cycles,
                            start_instret,
                        ));
                    }
                    self.wx(rd, next);
                    next = t;
                    self.bump_ref(&mut counts, OpClass::Jump, 1);
                }
                Jalr => {
                    let t = (self.x[rs1] as u32).wrapping_add(i.imm as u32) & !1;
                    self.wx(rd, next);
                    self.bump_ref(&mut counts, OpClass::Jump, 1);
                    if t % 4 != 0 {
                        return Err(self.trap_here(
                            TrapKind::MisalignedTarget { target: t },
                            pc,
                            start_cycles,
                            start_instret,
                        ));
                    }
                    next = t;
                }
                Beq | Bne | Blt | Bge => {
                    let a = self.x[rs1];
                    let b = self.x[rs2];
                    let taken = match i.op {
                        Beq => a == b,
                        Bne => a != b,
                        Blt => a < b,
                        _ => a >= b,
                    };
                    if taken {
                        let t = pc.wrapping_add(i.imm as u32);
                        if t % 4 != 0 {
                            return Err(self.trap_here(
                                TrapKind::MisalignedTarget { target: t },
                                pc,
                                start_cycles,
                                start_instret,
                            ));
                        }
                        next = t;
                        self.bump_ref(&mut counts, OpClass::Branch, 2);
                    } else {
                        self.bump_ref(&mut counts, OpClass::Branch, 1);
                    }
                }
                Lw => {
                    let addr = (self.x[rs1] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    let val = self
                        .load_u32(addr)
                        .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                    self.wx(rd, val);
                    self.bump_ref(&mut counts, OpClass::Load, lat);
                }
                Sw => {
                    let addr = (self.x[rs1] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.store_u32(addr, self.x[rs2] as u32)
                        .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                    self.bump_ref(&mut counts, OpClass::Store, lat.min(2));
                }
                Addi => { self.wxi(rd, self.x[rs1].wrapping_add(i.imm)); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Slti => { self.wxi(rd, (self.x[rs1] < i.imm) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Andi => { self.wxi(rd, self.x[rs1] & i.imm); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Ori => { self.wxi(rd, self.x[rs1] | i.imm); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Xori => { self.wxi(rd, self.x[rs1] ^ i.imm); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Slli => { self.wxi(rd, ((self.x[rs1] as u32) << i.imm) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Srli => { self.wxi(rd, ((self.x[rs1] as u32) >> i.imm) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Srai => { self.wxi(rd, self.x[rs1] >> i.imm); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Add => { self.wxi(rd, self.x[rs1].wrapping_add(self.x[rs2])); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Sub => { self.wxi(rd, self.x[rs1].wrapping_sub(self.x[rs2])); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Sll => { self.wxi(rd, ((self.x[rs1] as u32) << (self.x[rs2] & 31)) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Srl => { self.wxi(rd, ((self.x[rs1] as u32) >> (self.x[rs2] & 31)) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Sra => { self.wxi(rd, self.x[rs1] >> (self.x[rs2] & 31)); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                And => { self.wxi(rd, self.x[rs1] & self.x[rs2]); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Or => { self.wxi(rd, self.x[rs1] | self.x[rs2]); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Xor => { self.wxi(rd, self.x[rs1] ^ self.x[rs2]); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Slt => { self.wxi(rd, (self.x[rs1] < self.x[rs2]) as i32); self.bump_ref(&mut counts, OpClass::Alu, 1); }
                Mul => { self.wxi(rd, self.x[rs1].wrapping_mul(self.x[rs2])); self.bump_ref(&mut counts, OpClass::Mul, 3); }
                Mulh => {
                    let p = (self.x[rs1] as i64) * (self.x[rs2] as i64);
                    self.wxi(rd, (p >> 32) as i32);
                    self.bump_ref(&mut counts, OpClass::Mul, 3);
                }
                Div => {
                    let d = self.x[rs2];
                    self.wxi(rd, if d == 0 { -1 } else { self.x[rs1].wrapping_div(d) });
                    self.bump_ref(&mut counts, OpClass::Div, 20);
                }
                Rem => {
                    let d = self.x[rs2];
                    self.wxi(rd, if d == 0 { self.x[rs1] } else { self.x[rs1].wrapping_rem(d) });
                    self.bump_ref(&mut counts, OpClass::Div, 20);
                }
                Flw => {
                    let addr = (self.x[rs1] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.f[rd] = self
                        .load_f32(addr)
                        .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                    self.bump_ref(&mut counts, OpClass::Load, lat);
                }
                Fsw => {
                    let addr = (self.x[rs1] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.store_f32(addr, self.f[rs2])
                        .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                    self.bump_ref(&mut counts, OpClass::Store, lat.min(2));
                }
                FaddS => { self.f[rd] = self.f[rs1] + self.f[rs2]; self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FsubS => { self.f[rd] = self.f[rs1] - self.f[rs2]; self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FmulS => { self.f[rd] = self.f[rs1] * self.f[rs2]; self.bump_ref(&mut counts, OpClass::FMul, 3); }
                FdivS => { self.f[rd] = self.f[rs1] / self.f[rs2]; self.bump_ref(&mut counts, OpClass::FDiv, 16); }
                FmaddS => {
                    self.f[rd] = self.f[rs1] * self.f[rs2] + self.f[rs3];
                    self.bump_ref(&mut counts, OpClass::FMa, 4);
                }
                FminS => { self.f[rd] = self.f[rs1].min(self.f[rs2]); self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FmaxS => { self.f[rd] = self.f[rs1].max(self.f[rs2]); self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FcvtWS => { self.wxi(rd, self.f[rs1] as i32); self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FcvtSW => { self.f[rd] = self.x[rs1] as f32; self.bump_ref(&mut counts, OpClass::FAlu, 2); }
                FexpS => { self.f[rd] = self.f[rs1].exp(); self.bump_ref(&mut counts, OpClass::FCustom, 8); }
                FrsqrtS => { self.f[rd] = 1.0 / self.f[rs1].sqrt(); self.bump_ref(&mut counts, OpClass::FCustom, 8); }
                Vsetvli => {
                    if !self.cfg.has_vector {
                        return Err(self.ctx(scalar_only(), pc, start_cycles, start_instret));
                    }
                    self.lmul = 1 << rs3;
                    let vlmax = self.lanes * self.lmul;
                    let avl = self.x[rs1].max(0) as usize;
                    self.vl = avl.min(vlmax);
                    self.wxi(rd, self.vl as i32);
                    self.bump_ref(&mut counts, OpClass::VSet, 1);
                }
                Vle32 | Vle8 | Vse32 | Vse8 => {
                    if !self.cfg.has_vector {
                        return Err(self.ctx(scalar_only(), pc, start_cycles, start_instret));
                    }
                    let base = self.x[rs1] as u32;
                    let esz = if matches!(i.op, Vle32 | Vse32) { 4 } else { 1 };
                    // One cache access per line touched.
                    let bytes = self.vl * esz;
                    let mut lat = 0;
                    let mut a = base as u64;
                    while a < (base as u64) + bytes as u64 {
                        lat = lat.max(self.hier.access(a));
                        a += 64;
                    }
                    for e in 0..self.vl {
                        let addr = base + (e * esz) as u32;
                        match i.op {
                            Vle32 => {
                                let v = self
                                    .load_f32(addr)
                                    .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                                self.vreg_set_ref(rd, e, v);
                            }
                            Vse32 => {
                                let v = self.vreg_ref(rd, e);
                                self.store_f32(addr, v)
                                    .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?;
                            }
                            Vle8 => {
                                let b = self
                                    .mem_ref(addr, 1)
                                    .map_err(|e| self.ctx(e, pc, start_cycles, start_instret))?
                                    [0];
                                self.vreg_set_ref(rd, e, b as i8 as f32);
                            }
                            _ => {
                                let v = self.vreg_ref(rd, e);
                                match self.mem_mut(addr, 1) {
                                    Ok(b) => b[0] = (v as i32).clamp(-128, 127) as u8,
                                    Err(err) => {
                                        return Err(self.ctx(
                                            err,
                                            pc,
                                            start_cycles,
                                            start_instret,
                                        ))
                                    }
                                }
                            }
                        }
                    }
                    let class = if matches!(i.op, Vle32 | Vle8) { OpClass::VLoad } else { OpClass::VStore };
                    self.bump_ref(&mut counts, class, (self.vl as u64 / 4).max(1) + lat);
                }
                VaddVV | VfaddVV => {
                    for e in 0..self.vl {
                        let r = self.vreg_ref(rs1, e) + self.vreg_ref(rs2, e);
                        self.vreg_set_ref(rd, e, r);
                    }
                    self.bump_ref(&mut counts, OpClass::VAlu, self.lmul as u64);
                }
                VsubVV | VfsubVV => {
                    for e in 0..self.vl {
                        let r = self.vreg_ref(rs1, e) - self.vreg_ref(rs2, e);
                        self.vreg_set_ref(rd, e, r);
                    }
                    self.bump_ref(&mut counts, OpClass::VAlu, self.lmul as u64);
                }
                VmulVV | VfmulVV => {
                    for e in 0..self.vl {
                        let r = self.vreg_ref(rs1, e) * self.vreg_ref(rs2, e);
                        self.vreg_set_ref(rd, e, r);
                    }
                    self.bump_ref(&mut counts, OpClass::VMul, (2 * self.lmul) as u64);
                }
                VmaccVV | VfmaccVV => {
                    for e in 0..self.vl {
                        let acc = self.vreg_ref(rd, e)
                            + self.vreg_ref(rs1, e) * self.vreg_ref(rs2, e);
                        self.vreg_set_ref(rd, e, acc);
                    }
                    self.bump_ref(&mut counts, OpClass::VFma, (2 * self.lmul) as u64);
                }
                VfmaccVF => {
                    let s = self.f[rs1];
                    for e in 0..self.vl {
                        let acc = self.vreg_ref(rd, e) + s * self.vreg_ref(rs2, e);
                        self.vreg_set_ref(rd, e, acc);
                    }
                    self.bump_ref(&mut counts, OpClass::VFma, (2 * self.lmul) as u64);
                }
                VfredsumVS => {
                    let mut acc = self.vreg_ref(rs1, 0);
                    for e in 0..self.vl {
                        acc += self.vreg_ref(rs2, e);
                    }
                    self.vreg_set_ref(rd, 0, acc);
                    self.bump_ref(&mut counts, OpClass::VRed, 4 + self.lmul as u64);
                }
                VfmaxVV => {
                    for e in 0..self.vl {
                        let r = self.vreg_ref(rs1, e).max(self.vreg_ref(rs2, e));
                        self.vreg_set_ref(rd, e, r);
                    }
                    self.bump_ref(&mut counts, OpClass::VAlu, self.lmul as u64);
                }
                VfmvVF => {
                    let s = self.f[rs1];
                    for e in 0..self.vl {
                        self.vreg_set_ref(rd, e, s);
                    }
                    self.bump_ref(&mut counts, OpClass::VAlu, self.lmul as u64);
                }
            }
            pc = next;
        }
        for (c, n) in counts {
            self.class_counts[c.index()] += n;
        }
        Ok(self.stats_since(start_cycles, start_instret, &start_counts))
    }

    // -- inspection ----------------------------------------------------------

    /// The flat vector register file (register `i` at `i * lanes`).
    pub fn vreg_file(&self) -> &[f32] {
        &self.v
    }

    /// Nonzero per-class retirement counters (for the energy model).
    pub fn class_counts(&self) -> Vec<(OpClass, u64)> {
        OpClass::ALL
            .iter()
            .filter(|c| self.class_counts[c.index()] > 0)
            .map(|c| (*c, self.class_counts[c.index()]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode_all;
    use crate::isa::{Instr, Op};

    fn run(prog: &[Instr]) -> Machine {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let words = encode_all(prog).unwrap();
        m.run(&words).unwrap();
        m
    }

    #[test]
    fn arithmetic_basics() {
        let m = run(&[
            Instr::i(Op::Addi, 5, 0, 10),
            Instr::i(Op::Addi, 6, 0, 32),
            Instr::r(Op::Add, 7, 5, 6),
            Instr::r(Op::Mul, 28, 5, 6),
            Instr::r(Op::Sub, 29, 6, 5),
        ]);
        assert_eq!(m.x[7], 42);
        assert_eq!(m.x[28], 320);
        assert_eq!(m.x[29], 22);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let m = run(&[Instr::i(Op::Addi, 0, 0, 99)]);
        assert_eq!(m.x[0], 0);
    }

    #[test]
    fn loads_and_stores() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.write_f32_slice(0x100, &[1.5, 2.5]).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 0x100),
            Instr::i(Op::Flw, 1, 5, 0),
            Instr::i(Op::Flw, 2, 5, 4),
            Instr::r(Op::FaddS, 3, 1, 2),
            Instr::s(Op::Fsw, 5, 3, 8),
        ])
        .unwrap();
        m.run(&prog).unwrap();
        assert_eq!(m.load_f32(0x108).unwrap(), 4.0);
    }

    #[test]
    fn branch_loop_sums() {
        // for (i = 10; i != 0; i--) acc += i;  => 55
        let prog = vec![
            Instr::i(Op::Addi, 5, 0, 10),  // i = 10
            Instr::i(Op::Addi, 6, 0, 0),   // acc = 0
            Instr::r(Op::Add, 6, 6, 5),    // loop: acc += i
            Instr::i(Op::Addi, 5, 5, -1),  // i--
            Instr::b(Op::Bne, 5, 0, -8),   // if i != 0 goto loop
        ];
        let m = run(&prog);
        assert_eq!(m.x[6], 55);
    }

    #[test]
    fn vector_add_and_reduce() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let xs: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..8).map(|i| (i * 10) as f32).collect();
        m.write_f32_slice(0x000, &xs).unwrap();
        m.write_f32_slice(0x100, &ys).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 8),   // avl = 8
            {
                let mut i = Instr::new(Op::Vsetvli);
                i.rd = 6;
                i.rs1 = 5;
                i.rs3 = 0; // lmul=1
                i
            },
            Instr::i(Op::Addi, 7, 0, 0x000),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 1;
                i.rs1 = 7;
                i
            },
            Instr::i(Op::Addi, 7, 0, 0x100),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 2;
                i.rs1 = 7;
                i
            },
            Instr::r(Op::VfaddVV, 3, 1, 2),
            Instr::i(Op::Addi, 7, 0, 0x200),
            {
                let mut i = Instr::new(Op::Vse32);
                i.rd = 3;
                i.rs1 = 7;
                i
            },
        ])
        .unwrap();
        m.run(&prog).unwrap();
        let out = m.read_f32_slice(0x200, 8).unwrap();
        let want: Vec<f32> = (0..8).map(|i| (i + i * 10) as f32).collect();
        assert_eq!(out, want);
        assert_eq!(m.vl, 8);
    }

    #[test]
    fn vsetvli_clamps_to_vlmax() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let prog = encode_all(&[Instr::i(Op::Addi, 5, 0, 100), {
            let mut i = Instr::new(Op::Vsetvli);
            i.rd = 6;
            i.rs1 = 5;
            i.rs3 = 1; // lmul=2 -> vlmax = 16
            i
        }])
        .unwrap();
        m.run(&prog).unwrap();
        assert_eq!(m.x[6], 16);
        assert_eq!(m.vl, 16);
        assert_eq!(m.lmul, 2);
    }

    #[test]
    fn lmul_register_grouping() {
        // With lmul=2 a vector op spans v[rd] and v[rd+1].
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let xs: Vec<f32> = (0..16).map(|i| i as f32).collect();
        m.write_f32_slice(0x0, &xs).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 16),
            {
                let mut i = Instr::new(Op::Vsetvli);
                i.rd = 6;
                i.rs1 = 5;
                i.rs3 = 1;
                i
            },
            Instr::i(Op::Addi, 7, 0, 0),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 2;
                i.rs1 = 7;
                i
            },
            Instr::r(Op::VfaddVV, 4, 2, 2), // v4..v5 = 2*x
            Instr::i(Op::Addi, 7, 0, 0x100),
            {
                let mut i = Instr::new(Op::Vse32);
                i.rd = 4;
                i.rs1 = 7;
                i
            },
        ])
        .unwrap();
        m.run(&prog).unwrap();
        let out = m.read_f32_slice(0x100, 16).unwrap();
        assert_eq!(out, (0..16).map(|i| 2.0 * i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn fexp_custom_instruction() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.f[1] = 1.0;
        let prog = encode_all(&[Instr::r(Op::FexpS, 2, 1, 0)]).unwrap();
        m.run(&prog).unwrap();
        assert!((m.f[2] - std::f32::consts::E).abs() < 1e-6);
    }

    #[test]
    fn scalar_only_platform_rejects_vector() {
        let mut m = Machine::new(MachineConfig::cpu_a78());
        let prog = encode_all(&[{
            let mut i = Instr::new(Op::Vsetvli);
            i.rd = 6;
            i.rs1 = 5;
            i
        }])
        .unwrap();
        assert!(m.run(&prog).is_err());
    }

    #[test]
    fn oob_access_is_error_not_panic() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let prog = encode_all(&[
            Instr::u(Op::Lui, 5, 0x3FFFF), // near DMEM top (beyond allocation)
            Instr::i(Op::Lw, 6, 5, 0),
        ])
        .unwrap();
        assert!(m.run(&prog).is_err());
    }

    #[test]
    fn cycle_accounting_monotone() {
        let m1 = run(&[Instr::i(Op::Addi, 5, 0, 1)]);
        let m2 = run(&[
            Instr::i(Op::Addi, 5, 0, 1),
            Instr::r(Op::Div, 6, 5, 5),
            Instr::r(Op::Div, 7, 5, 5),
        ]);
        assert!(m2.cycles > m1.cycles + 20, "{} vs {}", m2.cycles, m1.cycles);
    }

    /// The fast path and the reference loop must agree exactly — stats and
    /// architectural state — on a branch-and-vector workout.
    #[test]
    fn fast_path_matches_reference_loop() {
        let xs: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 10),
            Instr::i(Op::Addi, 6, 0, 0),
            Instr::r(Op::Add, 6, 6, 5),
            Instr::i(Op::Addi, 5, 5, -1),
            Instr::b(Op::Bne, 5, 0, -8),
            Instr::i(Op::Addi, 5, 0, 16),
            {
                let mut i = Instr::new(Op::Vsetvli);
                i.rd = 6;
                i.rs1 = 5;
                i.rs3 = 1;
                i
            },
            Instr::i(Op::Addi, 7, 0, 0x40),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 2;
                i.rs1 = 7;
                i
            },
            Instr::r(Op::VfmaccVV, 4, 2, 2),
            Instr::i(Op::Addi, 7, 0, 0x140),
            {
                let mut i = Instr::new(Op::Vse32);
                i.rd = 4;
                i.rs1 = 7;
                i
            },
            Instr::u(Op::Jal, 1, 8), // skip the next word
            Instr::i(Op::Addi, 9, 0, 77),
        ])
        .unwrap();
        let mut fast = Machine::new(MachineConfig::xgen_asic());
        fast.write_f32_slice(0x40, &xs).unwrap();
        let sf = fast.run(&prog).unwrap();
        let mut rf = Machine::new(MachineConfig::xgen_asic());
        rf.write_f32_slice(0x40, &xs).unwrap();
        let sr = rf.run_reference(&prog).unwrap();
        assert_eq!(sf, sr);
        assert_eq!(fast.x, rf.x);
        assert_eq!(
            fast.vreg_file().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            rf.vreg_file().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(fast.hier.stats(), rf.hier.stats());
        assert_eq!(fast.x[9], 0, "jal must skip the trailing addi");
    }

    /// Illegal words fault only when executed — on both paths.
    #[test]
    fn illegal_word_faults_lazily_on_both_paths() {
        // jal jumps over the garbage word, so both paths succeed...
        let mut prog = encode_all(&[Instr::u(Op::Jal, 0, 8)]).unwrap();
        prog.push(0xFFFF_FFFF);
        prog.extend(encode_all(&[Instr::i(Op::Addi, 5, 0, 3)]).unwrap());
        let mut a = Machine::new(MachineConfig::xgen_asic());
        let mut b = Machine::new(MachineConfig::xgen_asic());
        assert_eq!(a.run(&prog).unwrap(), b.run_reference(&prog).unwrap());
        assert_eq!(a.x[5], 3);
        // ...but executing it errors identically.
        let bad = vec![0xFFFF_FFFFu32];
        let ea = Machine::new(MachineConfig::xgen_asic()).run(&bad).unwrap_err();
        let eb = Machine::new(MachineConfig::xgen_asic())
            .run_reference(&bad)
            .unwrap_err();
        assert_eq!(ea.to_string(), eb.to_string());
    }

    /// A conditional branch with a misaligned (encodable, 2-byte-multiple)
    /// taken-target must retire normally when not taken, and fault
    /// identically on both paths when taken.
    #[test]
    fn misaligned_branch_faults_only_when_taken() {
        let prog = encode_all(&[
            Instr::b(Op::Beq, 1, 2, 6),
            Instr::i(Op::Addi, 5, 0, 9),
        ])
        .unwrap();
        // Not taken (x1 != x2): both paths continue past it.
        let mut a = Machine::new(MachineConfig::xgen_asic());
        a.x[1] = 1;
        let mut b = Machine::new(MachineConfig::xgen_asic());
        b.x[1] = 1;
        assert_eq!(a.run(&prog).unwrap(), b.run_reference(&prog).unwrap());
        assert_eq!(a.x[5], 9);
        // Taken (x1 == x2 == 0): both paths fault, same message.
        let ea = Machine::new(MachineConfig::xgen_asic())
            .run(&prog)
            .unwrap_err()
            .to_string();
        let eb = Machine::new(MachineConfig::xgen_asic())
            .run_reference(&prog)
            .unwrap_err()
            .to_string();
        assert_eq!(ea, eb);
        assert!(ea.contains("misaligned"), "{ea}");
    }

    #[test]
    fn misaligned_jal_faults_on_both_paths() {
        let prog = encode_all(&[Instr::u(Op::Jal, 1, 6)]).unwrap();
        let ea = Machine::new(MachineConfig::xgen_asic())
            .run(&prog)
            .unwrap_err()
            .to_string();
        let eb = Machine::new(MachineConfig::xgen_asic())
            .run_reference(&prog)
            .unwrap_err()
            .to_string();
        assert_eq!(ea, eb);
        assert!(ea.contains("misaligned"), "{ea}");
    }

    #[test]
    fn instruction_budget_trips() {
        // An infinite loop: beq x0, x0, 0 (branch to self).
        let prog = encode_all(&[Instr::b(Op::Beq, 0, 0, 0)]).unwrap();
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.max_instret = 1000;
        let e = m.run(&prog).unwrap_err();
        assert!(e.to_string().contains("budget"), "{e}");
    }

    /// RunStats are per-run deltas on every axis: a second run on the same
    /// machine must not inherit the first run's class counts.
    #[test]
    fn run_stats_are_per_run_deltas() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let a = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 1),
            Instr::i(Op::Addi, 6, 0, 2),
            Instr::r(Op::Mul, 7, 5, 6),
        ])
        .unwrap();
        let b = encode_all(&[Instr::i(Op::Addi, 8, 0, 3)]).unwrap();
        m.run(&a).unwrap();
        let s2 = m.run(&b).unwrap();
        assert_eq!(s2.instret, 1);
        assert_eq!(s2.class_counts.values().sum::<u64>(), 1);
        assert_eq!(s2.class_counts.get("alu"), Some(&1));
        assert_eq!(s2.class_counts.get("mul"), None);
    }
}
