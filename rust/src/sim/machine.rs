//! Functional RV32I+RVV machine: fetch → decode → execute over *encoded*
//! binaries, with cycle and cache accounting.
//!
//! This is the hardware-in-the-loop stand-in: generated kernels actually run
//! here, numerics are compared against the IR executor, and the cycle
//! counts are the "measurements" the learned cost model trains on (small
//! kernels; the analytic `timing` model extrapolates for big ones and is
//! cross-validated against this machine).

use std::collections::BTreeMap;

use crate::isa::{decode, regs, Op, OpClass};
use crate::sim::cache::Hierarchy;
use crate::sim::{layout, MachineConfig};
use crate::util::error::{Error, Result};

/// Execution summary.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub cycles: u64,
    pub instret: u64,
    pub class_counts: BTreeMap<&'static str, u64>,
}

/// The simulated machine.
pub struct Machine {
    pub cfg: MachineConfig,
    pub x: [i32; 32],
    pub f: [f32; 32],
    /// Vector register file: 32 regs x lanes f32.
    pub v: Vec<Vec<f32>>,
    /// Active vector length (elements) and register-group multiplier.
    pub vl: usize,
    pub lmul: usize,
    dmem: Vec<u8>,
    wmem: Vec<u8>,
    pub cycles: u64,
    pub instret: u64,
    pub hier: Hierarchy,
    class_counts: BTreeMap<OpClass, u64>,
    /// Instruction budget guard against runaway programs.
    pub max_instret: u64,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Machine {
        let lanes = cfg.lanes();
        let hier = Hierarchy::new(&cfg.caches, cfg.mem_latency);
        // Cap host allocation: the address map allows huge DMEM/WMEM but the
        // tests only touch the low megabytes.
        let dmem = vec![0u8; cfg.dmem_bytes.min(64 << 20)];
        let wmem = vec![0u8; cfg.wmem_bytes.min(64 << 20)];
        let mut x = [0; 32];
        // ABI: stack pointer starts at DMEM top (grows down).
        x[regs::SP as usize] = dmem.len() as i32;
        Machine {
            cfg,
            x,
            f: [0.0; 32],
            v: vec![vec![0.0; lanes]; 32],
            vl: lanes,
            lmul: 1,
            dmem,
            wmem,
            cycles: 0,
            instret: 0,
            hier,
            class_counts: BTreeMap::new(),
            max_instret: 500_000_000,
        }
    }

    // -- memory ------------------------------------------------------------

    fn mem(&mut self, addr: u32) -> Result<(&mut Vec<u8>, usize)> {
        if addr >= layout::WMEM_BASE {
            let off = (addr - layout::WMEM_BASE) as usize;
            if off >= self.wmem.len() {
                return Err(Error::Sim(format!("WMEM OOB access at {addr:#010x}")));
            }
            Ok((&mut self.wmem, off))
        } else {
            let off = addr as usize;
            if off >= self.dmem.len() {
                return Err(Error::Sim(format!("DMEM OOB access at {addr:#010x}")));
            }
            Ok((&mut self.dmem, off))
        }
    }

    pub fn load_u32(&mut self, addr: u32) -> Result<u32> {
        let (m, o) = self.mem(addr)?;
        if o + 4 > m.len() {
            return Err(Error::Sim(format!("OOB word load at {addr:#010x}")));
        }
        Ok(u32::from_le_bytes([m[o], m[o + 1], m[o + 2], m[o + 3]]))
    }

    pub fn store_u32(&mut self, addr: u32, val: u32) -> Result<()> {
        let (m, o) = self.mem(addr)?;
        if o + 4 > m.len() {
            return Err(Error::Sim(format!("OOB word store at {addr:#010x}")));
        }
        m[o..o + 4].copy_from_slice(&val.to_le_bytes());
        Ok(())
    }

    pub fn load_f32(&mut self, addr: u32) -> Result<f32> {
        Ok(f32::from_bits(self.load_u32(addr)?))
    }

    pub fn store_f32(&mut self, addr: u32, val: f32) -> Result<()> {
        self.store_u32(addr, val.to_bits())
    }

    /// Bulk helpers for the test/bench harnesses.
    pub fn write_f32_slice(&mut self, addr: u32, vals: &[f32]) -> Result<()> {
        for (i, &v) in vals.iter().enumerate() {
            self.store_f32(addr + (i * 4) as u32, v)?;
        }
        Ok(())
    }

    pub fn read_f32_slice(&mut self, addr: u32, n: usize) -> Result<Vec<f32>> {
        (0..n).map(|i| self.load_f32(addr + (i * 4) as u32)).collect()
    }

    pub fn write_i8_slice(&mut self, addr: u32, vals: &[i8]) -> Result<()> {
        for (i, &v) in vals.iter().enumerate() {
            let (m, o) = self.mem(addr + i as u32)?;
            m[o] = v as u8;
        }
        Ok(())
    }

    // -- execution ----------------------------------------------------------

    fn bump(&mut self, class: OpClass, cycles: u64) {
        *self.class_counts.entry(class).or_insert(0) += 1;
        // Superscalar baselines retire multiple scalar ops per cycle.
        let scaled = if matches!(class, OpClass::Alu | OpClass::Branch | OpClass::Jump) {
            ((cycles as f64) / self.cfg.issue_width).ceil() as u64
        } else {
            cycles
        };
        self.cycles += scaled.max(1);
    }

    fn vreg(&self, base: u8, elem: usize) -> f32 {
        let lanes = self.cfg.lanes();
        self.v[base as usize + elem / lanes][elem % lanes]
    }

    fn vreg_set(&mut self, base: u8, elem: usize, val: f32) {
        let lanes = self.cfg.lanes();
        self.v[base as usize + elem / lanes][elem % lanes] = val;
    }

    /// Execute an encoded program until it falls off the end.
    /// Returns run statistics; machine state persists for inspection.
    pub fn run(&mut self, prog: &[u32]) -> Result<RunStats> {
        let start_instret = self.instret;
        let start_cycles = self.cycles;
        let end = (prog.len() * 4) as u32;
        let mut pc: u32 = 0;
        while pc < end {
            if self.instret - start_instret > self.max_instret {
                return Err(Error::Sim(format!(
                    "instruction budget exceeded ({})",
                    self.max_instret
                )));
            }
            let word = prog[(pc / 4) as usize];
            let i = decode::decode(word)?;
            self.instret += 1;
            let mut next = pc.wrapping_add(4);
            use Op::*;
            match i.op {
                // -- scalar integer ------------------------------------------
                Lui => {
                    self.wx(i.rd, (i.imm as u32) << 12);
                    self.bump(OpClass::Alu, 1);
                }
                Auipc => {
                    self.wx(i.rd, pc.wrapping_add((i.imm as u32) << 12));
                    self.bump(OpClass::Alu, 1);
                }
                Jal => {
                    self.wx(i.rd, next);
                    next = pc.wrapping_add(i.imm as u32);
                    self.bump(OpClass::Jump, 1);
                }
                Jalr => {
                    let t = (self.x[i.rs1 as usize] as u32).wrapping_add(i.imm as u32) & !1;
                    self.wx(i.rd, next);
                    next = t;
                    self.bump(OpClass::Jump, 1);
                }
                Beq | Bne | Blt | Bge => {
                    let a = self.x[i.rs1 as usize];
                    let b = self.x[i.rs2 as usize];
                    let taken = match i.op {
                        Beq => a == b,
                        Bne => a != b,
                        Blt => a < b,
                        _ => a >= b,
                    };
                    if taken {
                        next = pc.wrapping_add(i.imm as u32);
                        self.bump(OpClass::Branch, 2); // taken-branch penalty
                    } else {
                        self.bump(OpClass::Branch, 1);
                    }
                }
                Lw => {
                    let addr = (self.x[i.rs1 as usize] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    let val = self.load_u32(addr)?;
                    self.wx(i.rd, val);
                    self.bump(OpClass::Load, lat);
                }
                Sw => {
                    let addr = (self.x[i.rs1 as usize] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.store_u32(addr, self.x[i.rs2 as usize] as u32)?;
                    self.bump(OpClass::Store, lat.min(2)); // store buffer hides latency
                }
                Addi => { self.wxi(i.rd, self.x[i.rs1 as usize].wrapping_add(i.imm)); self.bump(OpClass::Alu, 1); }
                Slti => { self.wxi(i.rd, (self.x[i.rs1 as usize] < i.imm) as i32); self.bump(OpClass::Alu, 1); }
                Andi => { self.wxi(i.rd, self.x[i.rs1 as usize] & i.imm); self.bump(OpClass::Alu, 1); }
                Ori => { self.wxi(i.rd, self.x[i.rs1 as usize] | i.imm); self.bump(OpClass::Alu, 1); }
                Xori => { self.wxi(i.rd, self.x[i.rs1 as usize] ^ i.imm); self.bump(OpClass::Alu, 1); }
                Slli => { self.wxi(i.rd, ((self.x[i.rs1 as usize] as u32) << i.imm) as i32); self.bump(OpClass::Alu, 1); }
                Srli => { self.wxi(i.rd, ((self.x[i.rs1 as usize] as u32) >> i.imm) as i32); self.bump(OpClass::Alu, 1); }
                Srai => { self.wxi(i.rd, self.x[i.rs1 as usize] >> i.imm); self.bump(OpClass::Alu, 1); }
                Add => { self.wxi(i.rd, self.x[i.rs1 as usize].wrapping_add(self.x[i.rs2 as usize])); self.bump(OpClass::Alu, 1); }
                Sub => { self.wxi(i.rd, self.x[i.rs1 as usize].wrapping_sub(self.x[i.rs2 as usize])); self.bump(OpClass::Alu, 1); }
                Sll => { self.wxi(i.rd, ((self.x[i.rs1 as usize] as u32) << (self.x[i.rs2 as usize] & 31)) as i32); self.bump(OpClass::Alu, 1); }
                Srl => { self.wxi(i.rd, ((self.x[i.rs1 as usize] as u32) >> (self.x[i.rs2 as usize] & 31)) as i32); self.bump(OpClass::Alu, 1); }
                Sra => { self.wxi(i.rd, self.x[i.rs1 as usize] >> (self.x[i.rs2 as usize] & 31)); self.bump(OpClass::Alu, 1); }
                And => { self.wxi(i.rd, self.x[i.rs1 as usize] & self.x[i.rs2 as usize]); self.bump(OpClass::Alu, 1); }
                Or => { self.wxi(i.rd, self.x[i.rs1 as usize] | self.x[i.rs2 as usize]); self.bump(OpClass::Alu, 1); }
                Xor => { self.wxi(i.rd, self.x[i.rs1 as usize] ^ self.x[i.rs2 as usize]); self.bump(OpClass::Alu, 1); }
                Slt => { self.wxi(i.rd, (self.x[i.rs1 as usize] < self.x[i.rs2 as usize]) as i32); self.bump(OpClass::Alu, 1); }
                Mul => { self.wxi(i.rd, self.x[i.rs1 as usize].wrapping_mul(self.x[i.rs2 as usize])); self.bump(OpClass::Mul, 3); }
                Mulh => {
                    let p = (self.x[i.rs1 as usize] as i64) * (self.x[i.rs2 as usize] as i64);
                    self.wxi(i.rd, (p >> 32) as i32);
                    self.bump(OpClass::Mul, 3);
                }
                Div => {
                    let d = self.x[i.rs2 as usize];
                    self.wxi(i.rd, if d == 0 { -1 } else { self.x[i.rs1 as usize].wrapping_div(d) });
                    self.bump(OpClass::Div, 20);
                }
                Rem => {
                    let d = self.x[i.rs2 as usize];
                    self.wxi(i.rd, if d == 0 { self.x[i.rs1 as usize] } else { self.x[i.rs1 as usize].wrapping_rem(d) });
                    self.bump(OpClass::Div, 20);
                }

                // -- scalar float --------------------------------------------
                Flw => {
                    let addr = (self.x[i.rs1 as usize] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.f[i.rd as usize] = self.load_f32(addr)?;
                    self.bump(OpClass::Load, lat);
                }
                Fsw => {
                    let addr = (self.x[i.rs1 as usize] as u32).wrapping_add(i.imm as u32);
                    let lat = self.hier.access(addr as u64);
                    self.store_f32(addr, self.f[i.rs2 as usize])?;
                    self.bump(OpClass::Store, lat.min(2));
                }
                FaddS => { self.f[i.rd as usize] = self.f[i.rs1 as usize] + self.f[i.rs2 as usize]; self.bump(OpClass::FAlu, 2); }
                FsubS => { self.f[i.rd as usize] = self.f[i.rs1 as usize] - self.f[i.rs2 as usize]; self.bump(OpClass::FAlu, 2); }
                FmulS => { self.f[i.rd as usize] = self.f[i.rs1 as usize] * self.f[i.rs2 as usize]; self.bump(OpClass::FMul, 3); }
                FdivS => { self.f[i.rd as usize] = self.f[i.rs1 as usize] / self.f[i.rs2 as usize]; self.bump(OpClass::FDiv, 16); }
                FmaddS => {
                    self.f[i.rd as usize] =
                        self.f[i.rs1 as usize] * self.f[i.rs2 as usize] + self.f[i.rs3 as usize];
                    self.bump(OpClass::FMa, 4);
                }
                FminS => { self.f[i.rd as usize] = self.f[i.rs1 as usize].min(self.f[i.rs2 as usize]); self.bump(OpClass::FAlu, 2); }
                FmaxS => { self.f[i.rd as usize] = self.f[i.rs1 as usize].max(self.f[i.rs2 as usize]); self.bump(OpClass::FAlu, 2); }
                FcvtWS => { self.wxi(i.rd, self.f[i.rs1 as usize] as i32); self.bump(OpClass::FAlu, 2); }
                FcvtSW => { self.f[i.rd as usize] = self.x[i.rs1 as usize] as f32; self.bump(OpClass::FAlu, 2); }
                FexpS => { self.f[i.rd as usize] = self.f[i.rs1 as usize].exp(); self.bump(OpClass::FCustom, 8); }
                FrsqrtS => { self.f[i.rd as usize] = 1.0 / self.f[i.rs1 as usize].sqrt(); self.bump(OpClass::FCustom, 8); }

                // -- vector ---------------------------------------------------
                Vsetvli => {
                    if !self.cfg.has_vector {
                        return Err(Error::Sim("vector instruction on scalar-only platform".into()));
                    }
                    self.lmul = 1 << i.rs3;
                    let vlmax = self.cfg.lanes() * self.lmul;
                    let avl = self.x[i.rs1 as usize].max(0) as usize;
                    self.vl = avl.min(vlmax);
                    self.wxi(i.rd, self.vl as i32);
                    self.bump(OpClass::VSet, 1);
                }
                Vle32 | Vle8 | Vse32 | Vse8 => {
                    if !self.cfg.has_vector {
                        return Err(Error::Sim("vector instruction on scalar-only platform".into()));
                    }
                    let base = self.x[i.rs1 as usize] as u32;
                    let esz = if matches!(i.op, Vle32 | Vse32) { 4 } else { 1 };
                    // One cache access per line touched.
                    let bytes = self.vl * esz;
                    let mut lat = 0;
                    let mut a = base as u64;
                    while a < (base as u64) + bytes as u64 {
                        lat = lat.max(self.hier.access(a));
                        a += 64;
                    }
                    for e in 0..self.vl {
                        let addr = base + (e * esz) as u32;
                        match i.op {
                            Vle32 => {
                                let v = self.load_f32(addr)?;
                                self.vreg_set(i.rd, e, v);
                            }
                            Vse32 => {
                                let v = self.vreg(i.rd, e);
                                self.store_f32(addr, v)?;
                            }
                            Vle8 => {
                                let (m, o) = self.mem(addr)?;
                                let v = m[o] as i8 as f32;
                                self.vreg_set(i.rd, e, v);
                            }
                            _ => {
                                let v = self.vreg(i.rd, e);
                                let (m, o) = self.mem(addr)?;
                                m[o] = (v as i32).clamp(-128, 127) as u8 as u8;
                            }
                        }
                    }
                    let class = if matches!(i.op, Vle32 | Vle8) { OpClass::VLoad } else { OpClass::VStore };
                    // Throughput: lanes per cycle per port + miss latency.
                    self.bump(class, (self.vl as u64 / 4).max(1) + lat);
                }
                VaddVV | VfaddVV => self.vbin(&i, |a, b| a + b),
                VsubVV | VfsubVV => self.vbin(&i, |a, b| a - b),
                VmulVV | VfmulVV => self.vmul(&i),
                VmaccVV | VfmaccVV => self.vfma(&i),
                VfmaccVF => {
                    let s = self.f[i.rs1 as usize];
                    for e in 0..self.vl {
                        let acc = self.vreg(i.rd, e) + s * self.vreg(i.rs2, e);
                        self.vreg_set(i.rd, e, acc);
                    }
                    self.bump(OpClass::VFma, (2 * self.lmul) as u64);
                }
                VfredsumVS => {
                    let mut acc = self.vreg(i.rs1, 0);
                    for e in 0..self.vl {
                        acc += self.vreg(i.rs2, e);
                    }
                    self.vreg_set(i.rd, 0, acc);
                    self.bump(OpClass::VRed, 4 + self.lmul as u64);
                }
                VfmaxVV => self.vbin(&i, |a, b| a.max(b)),
                VfmvVF => {
                    let s = self.f[i.rs1 as usize];
                    for e in 0..self.vl {
                        self.vreg_set(i.rd, e, s);
                    }
                    self.bump(OpClass::VAlu, self.lmul as u64);
                }
            }
            pc = next;
        }
        Ok(RunStats {
            cycles: self.cycles - start_cycles,
            instret: self.instret - start_instret,
            class_counts: self
                .class_counts
                .iter()
                .map(|(k, v)| (class_name(*k), *v))
                .collect(),
        })
    }

    fn vbin(&mut self, i: &crate::isa::Instr, f: impl Fn(f32, f32) -> f32) {
        for e in 0..self.vl {
            let r = f(self.vreg(i.rs1, e), self.vreg(i.rs2, e));
            self.vreg_set(i.rd, e, r);
        }
        self.bump(OpClass::VAlu, self.lmul as u64);
    }

    fn vmul(&mut self, i: &crate::isa::Instr) {
        for e in 0..self.vl {
            let r = self.vreg(i.rs1, e) * self.vreg(i.rs2, e);
            self.vreg_set(i.rd, e, r);
        }
        self.bump(OpClass::VMul, (2 * self.lmul) as u64);
    }

    fn vfma(&mut self, i: &crate::isa::Instr) {
        // vmacc vd, vs1, vs2: vd += vs1 * vs2
        for e in 0..self.vl {
            let acc = self.vreg(i.rd, e) + self.vreg(i.rs1, e) * self.vreg(i.rs2, e);
            self.vreg_set(i.rd, e, acc);
        }
        self.bump(OpClass::VFma, (2 * self.lmul) as u64);
    }

    fn wx(&mut self, rd: u8, val: u32) {
        if rd != regs::ZERO {
            self.x[rd as usize] = val as i32;
        }
    }

    fn wxi(&mut self, rd: u8, val: i32) {
        if rd != regs::ZERO {
            self.x[rd as usize] = val;
        }
    }

    /// Class-count snapshot (for the energy model).
    pub fn class_counts(&self) -> &BTreeMap<OpClass, u64> {
        &self.class_counts
    }
}

fn class_name(c: OpClass) -> &'static str {
    match c {
        OpClass::Alu => "alu",
        OpClass::Mul => "mul",
        OpClass::Div => "div",
        OpClass::Branch => "branch",
        OpClass::Jump => "jump",
        OpClass::Load => "load",
        OpClass::Store => "store",
        OpClass::FAlu => "falu",
        OpClass::FMul => "fmul",
        OpClass::FDiv => "fdiv",
        OpClass::FMa => "fma",
        OpClass::FCustom => "fcustom",
        OpClass::VSet => "vset",
        OpClass::VLoad => "vload",
        OpClass::VStore => "vstore",
        OpClass::VAlu => "valu",
        OpClass::VMul => "vmul",
        OpClass::VFma => "vfma",
        OpClass::VRed => "vred",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode_all;
    use crate::isa::{Instr, Op};

    fn run(prog: &[Instr]) -> Machine {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let words = encode_all(prog).unwrap();
        m.run(&words).unwrap();
        m
    }

    #[test]
    fn arithmetic_basics() {
        let m = run(&[
            Instr::i(Op::Addi, 5, 0, 10),
            Instr::i(Op::Addi, 6, 0, 32),
            Instr::r(Op::Add, 7, 5, 6),
            Instr::r(Op::Mul, 28, 5, 6),
            Instr::r(Op::Sub, 29, 6, 5),
        ]);
        assert_eq!(m.x[7], 42);
        assert_eq!(m.x[28], 320);
        assert_eq!(m.x[29], 22);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let m = run(&[Instr::i(Op::Addi, 0, 0, 99)]);
        assert_eq!(m.x[0], 0);
    }

    #[test]
    fn loads_and_stores() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.write_f32_slice(0x100, &[1.5, 2.5]).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 0x100),
            Instr::i(Op::Flw, 1, 5, 0),
            Instr::i(Op::Flw, 2, 5, 4),
            Instr::r(Op::FaddS, 3, 1, 2),
            Instr::s(Op::Fsw, 5, 3, 8),
        ])
        .unwrap();
        m.run(&prog).unwrap();
        assert_eq!(m.load_f32(0x108).unwrap(), 4.0);
    }

    #[test]
    fn branch_loop_sums() {
        // for (i = 10; i != 0; i--) acc += i;  => 55
        let prog = vec![
            Instr::i(Op::Addi, 5, 0, 10),  // i = 10
            Instr::i(Op::Addi, 6, 0, 0),   // acc = 0
            Instr::r(Op::Add, 6, 6, 5),    // loop: acc += i
            Instr::i(Op::Addi, 5, 5, -1),  // i--
            Instr::b(Op::Bne, 5, 0, -8),   // if i != 0 goto loop
        ];
        let m = run(&prog);
        assert_eq!(m.x[6], 55);
    }

    #[test]
    fn vector_add_and_reduce() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let xs: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..8).map(|i| (i * 10) as f32).collect();
        m.write_f32_slice(0x000, &xs).unwrap();
        m.write_f32_slice(0x100, &ys).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 8),   // avl = 8
            {
                let mut i = Instr::new(Op::Vsetvli);
                i.rd = 6;
                i.rs1 = 5;
                i.rs3 = 0; // lmul=1
                i
            },
            Instr::i(Op::Addi, 7, 0, 0x000),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 1;
                i.rs1 = 7;
                i
            },
            Instr::i(Op::Addi, 7, 0, 0x100),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 2;
                i.rs1 = 7;
                i
            },
            Instr::r(Op::VfaddVV, 3, 1, 2),
            Instr::i(Op::Addi, 7, 0, 0x200),
            {
                let mut i = Instr::new(Op::Vse32);
                i.rd = 3;
                i.rs1 = 7;
                i
            },
        ])
        .unwrap();
        m.run(&prog).unwrap();
        let out = m.read_f32_slice(0x200, 8).unwrap();
        let want: Vec<f32> = (0..8).map(|i| (i + i * 10) as f32).collect();
        assert_eq!(out, want);
        assert_eq!(m.vl, 8);
    }

    #[test]
    fn vsetvli_clamps_to_vlmax() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let prog = encode_all(&[Instr::i(Op::Addi, 5, 0, 100), {
            let mut i = Instr::new(Op::Vsetvli);
            i.rd = 6;
            i.rs1 = 5;
            i.rs3 = 1; // lmul=2 -> vlmax = 16
            i
        }])
        .unwrap();
        m.run(&prog).unwrap();
        assert_eq!(m.x[6], 16);
        assert_eq!(m.vl, 16);
        assert_eq!(m.lmul, 2);
    }

    #[test]
    fn lmul_register_grouping() {
        // With lmul=2 a vector op spans v[rd] and v[rd+1].
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let xs: Vec<f32> = (0..16).map(|i| i as f32).collect();
        m.write_f32_slice(0x0, &xs).unwrap();
        let prog = encode_all(&[
            Instr::i(Op::Addi, 5, 0, 16),
            {
                let mut i = Instr::new(Op::Vsetvli);
                i.rd = 6;
                i.rs1 = 5;
                i.rs3 = 1;
                i
            },
            Instr::i(Op::Addi, 7, 0, 0),
            {
                let mut i = Instr::new(Op::Vle32);
                i.rd = 2;
                i.rs1 = 7;
                i
            },
            Instr::r(Op::VfaddVV, 4, 2, 2), // v4..v5 = 2*x
            Instr::i(Op::Addi, 7, 0, 0x100),
            {
                let mut i = Instr::new(Op::Vse32);
                i.rd = 4;
                i.rs1 = 7;
                i
            },
        ])
        .unwrap();
        m.run(&prog).unwrap();
        let out = m.read_f32_slice(0x100, 16).unwrap();
        assert_eq!(out, (0..16).map(|i| 2.0 * i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn fexp_custom_instruction() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        m.f[1] = 1.0;
        let prog = encode_all(&[Instr::r(Op::FexpS, 2, 1, 0)]).unwrap();
        m.run(&prog).unwrap();
        assert!((m.f[2] - std::f32::consts::E).abs() < 1e-6);
    }

    #[test]
    fn scalar_only_platform_rejects_vector() {
        let mut m = Machine::new(MachineConfig::cpu_a78());
        let prog = encode_all(&[{
            let mut i = Instr::new(Op::Vsetvli);
            i.rd = 6;
            i.rs1 = 5;
            i
        }])
        .unwrap();
        assert!(m.run(&prog).is_err());
    }

    #[test]
    fn oob_access_is_error_not_panic() {
        let mut m = Machine::new(MachineConfig::xgen_asic());
        let prog = encode_all(&[
            Instr::u(Op::Lui, 5, 0x3FFFF), // near DMEM top (beyond allocation)
            Instr::i(Op::Lw, 6, 5, 0),
        ])
        .unwrap();
        assert!(m.run(&prog).is_err());
    }

    #[test]
    fn cycle_accounting_monotone() {
        let m1 = run(&[Instr::i(Op::Addi, 5, 0, 1)]);
        let m2 = run(&[
            Instr::i(Op::Addi, 5, 0, 1),
            Instr::r(Op::Div, 6, 5, 5),
            Instr::r(Op::Div, 7, 5, 5),
        ]);
        assert!(m2.cycles > m1.cycles + 20, "{} vs {}", m2.cycles, m1.cycles);
    }
}
