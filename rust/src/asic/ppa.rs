//! PPA evaluation of a compiled program on a platform.

use crate::asic::params;
use crate::backend::memplan::MemPlan;
use crate::codegen::graphgen::Program;
use crate::ir::dtype::DType;
use crate::isa::OpClass;
use crate::sim::power;
use crate::sim::timing::{self, LoopNest};
use crate::sim::MachineConfig;

/// PPA of one compiled model on one platform (a Table 3 row).
#[derive(Debug, Clone)]
pub struct PpaReport {
    pub platform: String,
    /// ms per inference.
    pub latency_ms: f64,
    /// Average power in mW during inference.
    pub power_mw: f64,
    /// Silicon area in mm² (None for the off-the-shelf CPU, per Table 3).
    pub area_mm2: Option<f64>,
    pub cycles: f64,
    pub energy_mj: f64,
    pub flops: u64,
}

impl PpaReport {
    /// Effective GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / (self.latency_ms * 1e-3) / 1e9
    }
}

fn count_classes(nest: &LoopNest, counts: &mut [u64; OpClass::COUNT], mult: u64) {
    let m = mult * nest.trip;
    for (c, n) in nest.body.iter() {
        counts[c.index()] += n * m;
    }
    // Loop overhead retires as ALU work.
    counts[OpClass::Alu.index()] += nest.overhead * m;
    for child in &nest.children {
        count_classes(child, counts, m);
    }
}

/// Evaluate PPA for a lowered program at a datapath precision.
pub fn evaluate(
    mach: &MachineConfig,
    program: &Program,
    plan: &MemPlan,
    precision: DType,
) -> PpaReport {
    // -- Performance: analytic timing over every kernel ---------------------
    let mut cycles = 0.0;
    let mut class_counts = [0u64; OpClass::COUNT];
    let mut mem_bytes = 0u64;
    for (_, k) in &program.kernels {
        cycles += timing::estimate_cycles(mach, &k.nest, &k.mem, k.config.lmul);
        count_classes(&k.nest, &mut class_counts, 1);
        mem_bytes += k.mem.load_bytes + k.mem.store_bytes;
    }
    // Nonzero pairs for the energy model (its per-class weighting API).
    let counts: Vec<(OpClass, u64)> = OpClass::ALL
        .iter()
        .zip(class_counts.iter())
        .filter(|&(_, &n)| n != 0)
        .map(|(&c, &n)| (c, n))
        .collect();
    // Quantized datapaths also move fewer bytes per element.
    let byte_scale = precision.bits() as f64 / 32.0;
    // (Lane packing by precision is modeled inside the kernel profiles —
    // quantized kernels amortize per-group work over 32/bits more lanes.)
    let seconds = cycles / (mach.freq_mhz * 1e6);

    // -- Power ----------------------------------------------------------------
    let exec_pj = power::dynamic_energy_pj(&counts, precision);
    // Memory-hierarchy energy: per line touched at the (precision-scaled)
    // traffic, weighted by where the hit-rate model says accesses land.
    let line = mach.caches.first().map(|c| c.line).unwrap_or(64) as f64;
    let accesses = mem_bytes as f64 * byte_scale / line;
    let lvl_energy: f64 = mach
        .caches
        .iter()
        .enumerate()
        .map(|(i, c)| {
            // Geometric attenuation per level (deeper levels see fewer).
            let frac = 0.8f64.powi(i as i32) - 0.8f64.powi(i as i32 + 1);
            accesses * frac * c.energy_pj
        })
        .sum::<f64>()
        + accesses * 0.8f64.powi(mach.caches.len() as i32) * 640.0; // DRAM
    let total_pj = exec_pj + lvl_energy;
    let power_mw = power::average_power_mw(mach, total_pj, seconds);

    // -- Area -------------------------------------------------------------------
    let area_mm2 = if mach.name.contains("CPU") || !mach.has_vector {
        None // Table 3 reports N/A for the off-the-shelf CPU
    } else {
        let sram_mib = (mach.caches.iter().map(|c| c.size).sum::<usize>() as f64
            + plan.dmem_peak as f64 * 0.25) // quarter of peak activations resident
            / (1024.0 * 1024.0);
        let wmem_mib = ((plan.wmem_used as f64 * byte_scale) / (1024.0 * 1024.0))
            .min(params::WMEM_ONCHIP_CAP_MIB);
        let sram = (sram_mib + wmem_mib) * params::SRAM_MM2_PER_MIB;
        let datapath = params::DATAPATH_MM2_FP32 * params::datapath_scale(mach.native_dtype);
        let mut area = sram + datapath + params::OVERHEAD_MM2;
        if mach.name.contains("Hand") {
            area *= params::HAND_DESIGN_AREA_FACTOR;
        }
        Some(area)
    };

    PpaReport {
        platform: mach.name.clone(),
        latency_ms: seconds * 1e3,
        power_mw,
        area_mm2,
        cycles,
        energy_mj: total_pj * 1e-9,
        flops: program.flops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::memplan;
    use crate::codegen::graphgen::{self, Schedules};
    use crate::frontend::{model_zoo, prepare};

    fn compile_on(mach: &MachineConfig, precision: DType) -> PpaReport {
        // Through the full pipeline (optimization folds BatchNorm into the
        // convs — comparing unoptimized code would misattribute costs).
        let g = prepare(model_zoo::resnet_cifar(1)).unwrap();
        let mut session = crate::pipeline::CompileSession::new(crate::pipeline::CompileOptions {
            mach: mach.clone(),
            precision,
            ..Default::default()
        });
        session.compile(&g).unwrap().ppa
    }

    #[test]
    fn asic_beats_cpu_on_latency_and_power() {
        let asic = compile_on(&MachineConfig::xgen_asic(), DType::I8);
        let cpu = compile_on(&MachineConfig::cpu_a78(), DType::F32);
        assert!(
            asic.latency_ms < cpu.latency_ms,
            "asic {} vs cpu {}",
            asic.latency_ms,
            cpu.latency_ms
        );
        assert!(asic.power_mw < cpu.power_mw);
        assert!(asic.area_mm2.is_some());
        assert!(cpu.area_mm2.is_none(), "CPU area is N/A in Table 3");
    }

    #[test]
    fn xgen_smaller_than_hand_asic() {
        let xgen = compile_on(&MachineConfig::xgen_asic(), DType::I8);
        let hand = compile_on(&MachineConfig::hand_asic(), DType::F16);
        let (a, b) = (xgen.area_mm2.unwrap(), hand.area_mm2.unwrap());
        let reduction = 1.0 - a / b;
        assert!(
            (0.2..0.8).contains(&reduction),
            "area reduction {reduction} (xgen {a:.1} vs hand {b:.1})"
        );
        assert!(xgen.latency_ms < hand.latency_ms);
        assert!(xgen.power_mw < hand.power_mw);
    }

    #[test]
    fn quantization_reduces_power() {
        let mach = MachineConfig::xgen_asic();
        let fp32 = compile_on(&mach, DType::F32);
        let int8 = compile_on(&mach, DType::I8);
        assert!(int8.power_mw < fp32.power_mw);
        assert!(int8.energy_mj < fp32.energy_mj);
    }
}
