//! PPA (power / performance / area) model — the "hardware loss" that
//! validation-driven compilation feeds back into the cost model
//! (contribution 3), and the generator of Table 3 / Figures 2-4.
//!
//! First-order and calibrated (constants in [`params`]): what must hold is
//! the *mechanism* — quantization reduces switching energy and SRAM area,
//! tuning reduces cycles, the scalar CPU baseline burns wide-issue overhead
//! — not absolute silicon numbers (DESIGN.md §Substitutions).

pub mod params;
pub mod ppa;

pub use ppa::{evaluate, PpaReport};
