//! Calibrated PPA constants, with derivations.
//!
//! Technology assumption: a mature 16/12nm-class planar node (the paper
//! never states one). Energy constants live in `sim::power`; this module
//! holds the area model and platform-level overheads.

use crate::ir::dtype::DType;

/// SRAM macro density in mm² per MiB (16nm-class: ~0.45 mm²/MiB for
/// high-density single-port macros).
pub const SRAM_MM2_PER_MIB: f64 = 0.45;

/// On-chip weight-memory capacity cap in MiB: models keep a working set of
/// weights resident; the remainder streams from package DRAM (the paper's
/// per-model areas of 3-10 mm² are only consistent with partial residency).
pub const WMEM_ONCHIP_CAP_MIB: f64 = 8.0;

/// Datapath (MAC array + vector unit) area for a 32-bit 8-lane pipeline.
pub const DATAPATH_MM2_FP32: f64 = 1.9;

/// Control / NoC / IO overhead per accelerator instance.
pub const OVERHEAD_MM2: f64 = 0.8;

/// Multiplier area scales ~quadratically with operand width; wires and
/// adders linearly. Blend exponent 1.5 (slightly flatter than energy's 1.6
/// because register files don't shrink as fast).
pub fn datapath_scale(dt: DType) -> f64 {
    (dt.bits() as f64 / 32.0).powf(1.5).max(0.05)
}

/// Hand-designed-ASIC area penalty: no unified cost model across the stack
/// means conservatively-sized SRAMs, duplicated buffers, and a general-
/// purpose datapath (the paper attributes its 40-60% area win to exactly
/// these; we take a fixed 1.9x structural factor plus its FP16 datapath).
pub const HAND_DESIGN_AREA_FACTOR: f64 = 1.9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datapath_scale_monotone() {
        assert!(datapath_scale(DType::I8) < datapath_scale(DType::F16));
        assert!(datapath_scale(DType::F16) < datapath_scale(DType::F32));
        assert!((datapath_scale(DType::F32) - 1.0).abs() < 1e-12);
    }
}
