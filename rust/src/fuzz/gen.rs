//! Seeded random-graph generator for the compiler fuzzer.
//!
//! Given a `u64` seed, [`generate`] deterministically builds a small random
//! graph out of the op/shape space the backend supports end-to-end: dense
//! Gemm/MatMul chains with fan-out, residual adds, elementwise pairs and
//! shared initializers, or NCHW conv stacks with BatchNorm, depthwise convs,
//! pooling and a classifier tail. Shape menus deliberately include
//! degenerate extents (dim = 1, single-node chains, channel count 1) so
//! boundary paths in memory planning and codegen get exercised, and a
//! fraction of dense graphs are born with a symbolic batch dimension and
//! pushed through [`crate::dynshape::specialize`].
//!
//! Every generated graph is returned *prepared* (checked + shape-inferred)
//! and fully static, ready for [`crate::pipeline::session::CompileSession`].

use std::collections::BTreeSet;

use crate::ir::ops::{AttrValue, Attrs, OpKind};
use crate::ir::tensor::Initializer;
use crate::ir::{DType, Dim, Graph, Shape, TensorId};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Knobs for one generated graph.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Upper bound on the random step budget; the conv classifier tail can
    /// push the node count slightly past this.
    pub max_nodes: usize,
    /// Allow symbolic batch dimensions (exercises `dynshape::specialize`).
    pub allow_dynamic: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_nodes: 12, allow_dynamic: true }
    }
}

/// One generated test case.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Prepared (checked + shape-inferred), fully static graph.
    pub graph: Graph,
    /// Op name of every generated node, for coverage accounting.
    pub ops: Vec<&'static str>,
    /// Whether the graph was born with a symbolic batch and specialized.
    pub dynamic: bool,
}

const DENSE_BATCHES: [usize; 5] = [1, 1, 2, 3, 5];
const DENSE_FEATS: [usize; 6] = [1, 2, 4, 8, 12, 16];
const DENSE_ACTS: [OpKind; 7] = [
    OpKind::Relu,
    OpKind::Relu6,
    OpKind::Sigmoid,
    OpKind::Tanh,
    OpKind::Gelu,
    OpKind::Abs,
    OpKind::Neg,
];
const BIN_OPS: [OpKind; 4] = [OpKind::Add, OpKind::Sub, OpKind::Mul, OpKind::Max];
const CONV_BATCHES: [usize; 3] = [1, 1, 2];
const CONV_CINS: [usize; 3] = [1, 3, 4];
const CONV_HWS: [usize; 3] = [4, 6, 8];
const CONV_COUTS: [usize; 4] = [1, 2, 4, 8];
const CONV_CLASSES: [usize; 4] = [1, 2, 4, 10];

fn attrs(kv: &[(&str, AttrValue)]) -> Attrs {
    kv.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

fn ints(v: &[i64]) -> AttrValue {
    AttrValue::Ints(v.to_vec())
}

/// Graph-under-construction plus the deterministic state that drives it.
struct Builder {
    g: Graph,
    rng: Rng,
    wseed: u64,
    uid: usize,
    ops: Vec<&'static str>,
    exposed: BTreeSet<TensorId>,
}

impl Builder {
    fn name(&mut self, stem: &str) -> String {
        self.uid += 1;
        format!("{stem}{}", self.uid)
    }

    fn weight(&mut self, stem: &str, shape: &[usize], std: f32) -> TensorId {
        let nm = self.name(stem);
        self.wseed += 1;
        self.g.init(Initializer::lazy(&nm, shape, self.wseed, std))
    }

    fn push(&mut self, op: OpKind, stem: &str, inputs: &[TensorId], at: Attrs) -> TensorId {
        let nm = self.name(stem);
        self.ops.push(op.name());
        self.g.node(op, &nm, inputs, at)
    }

    /// Occasionally expose an intermediate as an extra graph output —
    /// multi-output graphs are where DCE/fusion passes historically clobber
    /// model outputs.
    fn maybe_expose(&mut self, t: TensorId) {
        if self.rng.chance(0.15) {
            self.exposed.insert(t);
        }
    }
}

/// Dense world: Gemm/MatMul chains over `[batch, feat]` activations.
/// Symbolic-batch graphs restrict the menu to the batch-agnostic ops
/// (Gemm / activation / residual / self-add).
fn build_dense(b: &mut Builder, cfg: &GenConfig, dynamic: bool) -> usize {
    let batch = DENSE_BATCHES[b.rng.index(DENSE_BATCHES.len())];
    let batch_dim = if dynamic {
        Dim::sym("batch", 1, 8)
    } else {
        Dim::Fixed(batch)
    };
    let mut feat = DENSE_FEATS[b.rng.index(DENSE_FEATS.len())];
    let x = b.g.input("x", Shape(vec![batch_dim, Dim::Fixed(feat)]), DType::F32);
    let mut cur = x;
    // Pooled (din, dout, weight, bias) for shared-initializer fan-out.
    let mut pool: Vec<(usize, usize, TensorId, TensorId)> = Vec::new();
    let budget = 1 + b.rng.index(cfg.max_nodes.max(1));
    let mut made = 0usize;
    while made < budget {
        // The first step is always a Gemm so every graph has real compute.
        let r = if made == 0 { 0.0 } else { b.rng.f64() };
        if r < 0.30 {
            let reuse =
                b.rng.chance(0.25) && pool.iter().any(|(din, ..)| *din == feat);
            let (dout, w, bias) = if reuse {
                let hits: Vec<(usize, TensorId, TensorId)> = pool
                    .iter()
                    .filter(|(din, ..)| *din == feat)
                    .map(|e| (e.1, e.2, e.3))
                    .collect();
                hits[b.rng.index(hits.len())]
            } else {
                let dout = DENSE_FEATS[b.rng.index(DENSE_FEATS.len())];
                let std = (2.0 / feat as f32).sqrt();
                let w = b.weight("w", &[feat, dout], std);
                let bias = b.weight("b", &[dout], 0.01);
                pool.push((feat, dout, w, bias));
                (dout, w, bias)
            };
            cur = b.push(OpKind::Gemm, "fc", &[cur, w, bias], Attrs::new());
            feat = dout;
            made += 1;
        } else if r < 0.40 && !dynamic {
            // MatMul + explicit rank-1 bias Add: the exact pattern
            // `FuseBiasAdd` rewrites into a Gemm.
            let dout = DENSE_FEATS[b.rng.index(DENSE_FEATS.len())];
            let std = (2.0 / feat as f32).sqrt();
            let w = b.weight("mw", &[feat, dout], std);
            let mm = b.push(OpKind::MatMul, "mm", &[cur, w], Attrs::new());
            let bias = b.weight("mb", &[dout], 0.01);
            cur = b.push(OpKind::Add, "biasadd", &[mm, bias], Attrs::new());
            feat = dout;
            made += 2;
        } else if r < 0.65 {
            let act = DENSE_ACTS[b.rng.index(DENSE_ACTS.len())];
            cur = b.push(act, "act", &[cur], Attrs::new());
            made += 1;
        } else if r < 0.80 {
            // Residual block: branch Gemm (feat -> feat) + Relu + Add back.
            let std = (2.0 / feat as f32).sqrt();
            let w = b.weight("rw", &[feat, feat], std);
            let bias = b.weight("rb", &[feat], 0.01);
            let y = b.push(OpKind::Gemm, "rfc", &[cur, w, bias], Attrs::new());
            let a = b.push(OpKind::Relu, "ract", &[y], Attrs::new());
            cur = b.push(OpKind::Add, "res", &[a, cur], Attrs::new());
            made += 3;
        } else if r < 0.85 {
            // Same tensor on both sides of a binary op.
            cur = b.push(OpKind::Add, "dbl", &[cur, cur], Attrs::new());
            made += 1;
        } else if r < 0.95 && !dynamic {
            // Fan a pair of activations out of `cur`, join with a binary op.
            let p = b.push(OpKind::Sigmoid, "pa", &[cur], Attrs::new());
            let q = b.push(OpKind::Tanh, "pb", &[cur], Attrs::new());
            let bin = BIN_OPS[b.rng.index(BIN_OPS.len())];
            cur = b.push(bin, "join", &[p, q], Attrs::new());
            made += 3;
        } else if !dynamic {
            cur = b.push(OpKind::Softmax, "sm", &[cur], Attrs::new());
            made += 1;
        } else {
            cur = b.push(OpKind::Relu, "act", &[cur], Attrs::new());
            made += 1;
        }
        b.maybe_expose(cur);
    }
    b.exposed.insert(cur);
    batch
}

/// Conv world: NCHW stacks of Conv/BN/depthwise/pool with an optional
/// GlobalAveragePool -> Flatten -> Gemm classifier tail.
fn build_conv(b: &mut Builder, cfg: &GenConfig) {
    let batch = CONV_BATCHES[b.rng.index(CONV_BATCHES.len())];
    let mut c = CONV_CINS[b.rng.index(CONV_CINS.len())];
    let mut hw = CONV_HWS[b.rng.index(CONV_HWS.len())];
    let x = b.g.input("x", Shape::fixed(&[batch, c, hw, hw]), DType::F32);
    let mut cur = x;
    // Pooled (cin, cout, k, weight, bias) for shared conv filters.
    let mut pool: Vec<(usize, usize, usize, TensorId, Option<TensorId>)> = Vec::new();
    let budget = 1 + b.rng.index(cfg.max_nodes.max(1));
    let mut made = 0usize;
    while made < budget {
        let r = if made == 0 { 0.0 } else { b.rng.f64() };
        if r < 0.40 {
            let k = if hw >= 3 && b.rng.chance(0.7) { 3 } else { 1 };
            let s = if hw >= 4 && b.rng.chance(0.3) { 2 } else { 1 };
            let p = k / 2;
            let reuse =
                b.rng.chance(0.2) && pool.iter().any(|e| e.0 == c && e.2 == k);
            let (cout, w, bias) = if reuse {
                let hits: Vec<(usize, TensorId, Option<TensorId>)> = pool
                    .iter()
                    .filter(|e| e.0 == c && e.2 == k)
                    .map(|e| (e.1, e.3, e.4))
                    .collect();
                hits[b.rng.index(hits.len())]
            } else {
                let cout = CONV_COUTS[b.rng.index(CONV_COUTS.len())];
                let std = (2.0 / (c * k * k) as f32).sqrt();
                let w = b.weight("cw", &[cout, c, k, k], std);
                let bias = if b.rng.chance(0.7) {
                    Some(b.weight("cb", &[cout], 0.01))
                } else {
                    None
                };
                pool.push((c, cout, k, w, bias));
                (cout, w, bias)
            };
            let at = attrs(&[
                ("strides", ints(&[s as i64, s as i64])),
                ("pads", ints(&[p as i64, p as i64])),
            ]);
            let inputs: Vec<TensorId> = match bias {
                Some(bi) => vec![cur, w, bi],
                None => vec![cur, w],
            };
            cur = b.push(OpKind::Conv, "conv", &inputs, at);
            c = cout;
            hw = (hw + 2 * p - k) / s + 1;
            made += 1;
        } else if r < 0.60 {
            let gamma = b.weight("gamma", &[c], 0.1);
            let beta = b.weight("beta", &[c], 0.01);
            let mean = b.weight("mean", &[c], 0.01);
            let vname = b.name("var");
            let var = b.g.init(Initializer::eager(&vname, &[c], vec![1.0; c]));
            cur = b.push(
                OpKind::BatchNormalization,
                "bn",
                &[cur, gamma, beta, mean, var],
                Attrs::new(),
            );
            made += 1;
        } else if r < 0.70 && hw >= 3 {
            // Depthwise 3x3 stride-1 + Relu6 (MobileNet idiom).
            let std = (2.0f32 / 9.0).sqrt();
            let w = b.weight("dw", &[c, 1, 3, 3], std);
            let at = attrs(&[("strides", ints(&[1, 1])), ("pads", ints(&[1, 1]))]);
            let y = b.push(OpKind::DepthwiseConv, "dwc", &[cur, w], at);
            cur = b.push(OpKind::Relu6, "dwa", &[y], Attrs::new());
            made += 2;
        } else if r < 0.85 {
            let act = if b.rng.chance(0.5) { OpKind::Relu } else { OpKind::Relu6 };
            cur = b.push(act, "act", &[cur], Attrs::new());
            made += 1;
        } else if r < 0.95 && hw >= 3 {
            let at = attrs(&[
                ("kernel_shape", ints(&[3, 3])),
                ("strides", ints(&[2, 2])),
                ("pads", ints(&[1, 1])),
            ]);
            cur = b.push(OpKind::MaxPool, "pool", &[cur], at);
            hw = (hw - 1) / 2 + 1;
            made += 1;
        } else if hw >= 3 {
            // Residual: Conv (c -> c, 3x3 s1 p1) + Relu + Add back.
            let std = (2.0 / (c * 9) as f32).sqrt();
            let w = b.weight("rcw", &[c, c, 3, 3], std);
            let bias = b.weight("rcb", &[c], 0.01);
            let at = attrs(&[("strides", ints(&[1, 1])), ("pads", ints(&[1, 1]))]);
            let y = b.push(OpKind::Conv, "rconv", &[cur, w, bias], at);
            let a = b.push(OpKind::Relu, "rrelu", &[y], Attrs::new());
            cur = b.push(OpKind::Add, "radd", &[a, cur], Attrs::new());
            made += 3;
        } else {
            cur = b.push(OpKind::Relu, "act", &[cur], Attrs::new());
            made += 1;
        }
        b.maybe_expose(cur);
    }
    if b.rng.chance(0.8) {
        let gap = b.push(OpKind::GlobalAveragePool, "gap", &[cur], Attrs::new());
        let flat = b.push(
            OpKind::Flatten,
            "flat",
            &[gap],
            attrs(&[("axis", AttrValue::Int(1))]),
        );
        let classes = CONV_CLASSES[b.rng.index(CONV_CLASSES.len())];
        let std = (2.0 / c as f32).sqrt();
        let w = b.weight("hw", &[c, classes], std);
        let bias = b.weight("hb", &[classes], 0.01);
        cur = b.push(OpKind::Gemm, "head", &[flat, w, bias], Attrs::new());
    }
    b.exposed.insert(cur);
}

/// Deterministically generate, check and shape-infer one random graph.
/// Same `(seed, cfg)` always yields an identical graph.
pub fn generate(seed: u64, cfg: &GenConfig) -> Result<Generated> {
    let rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
    let mut b = Builder {
        g: Graph::new(&format!("fuzz_{seed}")),
        rng,
        wseed: seed.wrapping_mul(1009),
        uid: 0,
        ops: Vec::new(),
        exposed: BTreeSet::new(),
    };
    let dense = b.rng.chance(0.6);
    let mut dynamic = false;
    let mut batch = 1usize;
    if dense {
        dynamic = cfg.allow_dynamic && b.rng.chance(0.2);
        batch = build_dense(&mut b, cfg, dynamic);
    } else {
        build_conv(&mut b, cfg);
    }
    b.g.outputs = b.exposed.iter().copied().collect();
    let prepared = crate::frontend::prepare(b.g)?;
    let graph = if dynamic {
        let sp = crate::dynshape::specialize(&prepared, &[("batch".to_string(), batch)])?;
        crate::frontend::prepare(sp)?
    } else {
        prepared
    };
    Ok(Generated { graph, ops: b.ops, dynamic })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = GenConfig::default();
        for seed in 0..20 {
            let a = generate(seed, &cfg).unwrap();
            let b = generate(seed, &cfg).unwrap();
            assert_eq!(a.ops, b.ops, "seed {seed} op sequence diverged");
            assert_eq!(a.graph.nodes.len(), b.graph.nodes.len());
            assert_eq!(a.graph.outputs, b.graph.outputs);
            assert_eq!(a.dynamic, b.dynamic);
        }
    }

    #[test]
    fn generated_graphs_are_prepared_and_static() {
        let cfg = GenConfig::default();
        for seed in 0..50 {
            let t = generate(seed, &cfg).unwrap();
            assert!(t.graph.check().is_ok(), "seed {seed} failed check");
            assert!(!t.graph.has_symbolic_dims(), "seed {seed} left symbolic dims");
            assert!(!t.graph.outputs.is_empty());
            for out in &t.graph.outputs {
                assert!(t.graph.tensors[out.0].shape.is_some(), "seed {seed} output unannotated");
            }
        }
    }

    #[test]
    fn seeds_cover_both_worlds_and_dynamic_batches() {
        let cfg = GenConfig::default();
        let mut conv = 0;
        let mut dense = 0;
        let mut dynamic = 0;
        for seed in 0..60 {
            let t = generate(seed, &cfg).unwrap();
            if t.ops.iter().any(|o| *o == "Conv" || *o == "MaxPool") {
                conv += 1;
            } else {
                dense += 1;
            }
            if t.dynamic {
                dynamic += 1;
            }
        }
        assert!(conv > 5, "conv world under-sampled: {conv}");
        assert!(dense > 5, "dense world under-sampled: {dense}");
        assert!(dynamic > 0, "no dynamic graphs in 60 seeds");
    }

    #[test]
    fn oracle_executes_generated_graphs() {
        use crate::ir::exec::Executor;
        use crate::runtime::simrun::synth_inputs;
        let cfg = GenConfig::default();
        for seed in 0..30 {
            let t = generate(seed, &cfg).unwrap();
            let inputs = synth_inputs(&t.graph, seed);
            let outs = Executor::new().run(&t.graph, &inputs).unwrap();
            assert_eq!(outs.len(), t.graph.outputs.len(), "seed {seed}");
            for o in &outs {
                assert!(o.data.iter().all(|v| v.is_finite()), "seed {seed} non-finite output");
            }
        }
    }
}
