//! Compiler fuzzing: seeded random graphs driven end-to-end through the
//! pipeline with differential verification against the reference executor.
//!
//! Each seed deterministically yields one graph ([`gen::generate`]); the
//! harness compiles it at every requested precision with pass-boundary IR
//! validation forced on, runs the static binary verifier ([`crate::analysis`])
//! over the emitted program as a zero-execution stage, then runs the binary on
//! the fast simulator and compares machine outputs against the
//! [`crate::ir::exec`] oracle under the precision's tolerance
//! ([`crate::runtime::simrun::tolerance`]). Any panic, compile/validator
//! error, static-verifier error finding, simulator trap, or numerical
//! divergence is a [`Finding`]; findings are shrunk to minimal reproducers by
//! [`reduce::reduce`] and serialized as ONNX-JSON for regression capture.
//!
//! The campaign is deterministic regardless of worker count: seeds are
//! index-striped across threads and results merged in seed order.

pub mod gen;
pub mod reduce;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::ir::{DType, Graph};
use crate::pipeline::session::{CompileOptions, CompileSession, CompiledModel};
use crate::runtime::simrun;
use crate::util::error::Error;
use crate::util::json::Json;

pub use gen::{GenConfig, Generated};

/// How a fuzz case failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FindingKind {
    /// A stage panicked (caught at the case boundary).
    Panic,
    /// Compilation failed: frontend, optimizer (including the per-pass IR
    /// validator), quantizer, codegen, or backend returned an error on a
    /// graph the generator considers well-formed.
    CompileError,
    /// The static binary verifier reported an Error-level finding on the
    /// emitted program — caught without executing a single instruction.
    Static,
    /// The simulator trapped or errored while executing the binary.
    SimError,
    /// Machine outputs diverged from the reference executor beyond the
    /// precision's tolerance.
    Divergence,
}

impl FindingKind {
    pub fn name(self) -> &'static str {
        match self {
            FindingKind::Panic => "panic",
            FindingKind::CompileError => "compile_error",
            FindingKind::Static => "static",
            FindingKind::SimError => "sim_error",
            FindingKind::Divergence => "divergence",
        }
    }
}

/// One failing fuzz case: the seed and precision that reproduce it, what
/// went wrong, and the offending graph (plus its reduction, when run).
#[derive(Debug, Clone)]
pub struct Finding {
    pub seed: u64,
    pub precision: DType,
    pub kind: FindingKind,
    pub detail: String,
    /// The full generated graph that failed.
    pub graph: Graph,
    /// Delta-debugged minimal graph reproducing the same failure signature.
    pub reduced: Option<Graph>,
}

impl Finding {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seed", Json::Num(self.seed as f64)),
            ("precision", Json::str_(self.precision.name())),
            ("kind", Json::str_(self.kind.name())),
            ("detail", Json::str_(&self.detail)),
            ("nodes", Json::Num(self.graph.nodes.len() as f64)),
            ("graph", Json::str_(&crate::frontend::onnx_json::save_str(&self.graph))),
        ];
        if let Some(r) = &self.reduced {
            fields.push(("reduced_nodes", Json::Num(r.nodes.len() as f64)));
            fields.push(("reduced", Json::str_(&crate::frontend::onnx_json::save_str(r))));
        }
        Json::obj(fields)
    }

    pub fn headline(&self) -> String {
        format!(
            "seed {} @ {}: {} ({})",
            self.seed,
            self.precision.name(),
            self.kind.name(),
            self.detail
        )
    }
}

/// Campaign configuration.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Number of seeds (graphs) to generate.
    pub seeds: u64,
    /// First seed; the campaign covers `start_seed .. start_seed + seeds`.
    pub start_seed: u64,
    /// Precisions each graph is compiled and verified at.
    pub precisions: Vec<DType>,
    pub gen: GenConfig,
    /// Worker threads (0 = available parallelism). Worker count never
    /// changes the result, only the wall clock.
    pub workers: usize,
    /// Shrink each finding to a minimal reproducer before reporting.
    pub reduce: bool,
}

impl Default for FuzzOptions {
    fn default() -> Self {
        FuzzOptions {
            seeds: 100,
            start_seed: 0,
            precisions: vec![DType::F32, DType::I8, DType::I4],
            gen: GenConfig::default(),
            workers: 0,
            reduce: true,
        }
    }
}

/// Campaign results: coverage accounting plus every finding.
#[derive(Debug, Clone, Default)]
pub struct FuzzReport {
    /// Graphs successfully generated.
    pub graphs: usize,
    /// Compile+verify runs (graphs x precisions).
    pub runs: usize,
    /// Graphs that went through symbolic-batch specialization.
    pub dynamic_graphs: usize,
    /// Generated node count per op name.
    pub op_coverage: BTreeMap<String, usize>,
    /// Runs per precision name.
    pub precision_runs: BTreeMap<String, usize>,
    pub findings: Vec<Finding>,
    pub wall_seconds: f64,
}

impl FuzzReport {
    pub fn graphs_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.graphs as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        let cov: Vec<(&str, Json)> = self
            .op_coverage
            .iter()
            .map(|(k, v)| (k.as_str(), Json::Num(*v as f64)))
            .collect();
        let prec: Vec<(&str, Json)> = self
            .precision_runs
            .iter()
            .map(|(k, v)| (k.as_str(), Json::Num(*v as f64)))
            .collect();
        Json::obj(vec![
            ("graphs", Json::Num(self.graphs as f64)),
            ("runs", Json::Num(self.runs as f64)),
            ("dynamic_graphs", Json::Num(self.dynamic_graphs as f64)),
            ("graphs_per_sec", Json::Num(self.graphs_per_sec())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("op_coverage", Json::obj(cov)),
            ("precision_runs", Json::obj(prec)),
            ("findings_count", Json::Num(self.findings.len() as f64)),
            (
                "findings",
                Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "{} graphs ({} dynamic), {} runs across {} precisions, {} ops covered, {} findings in {:.1}s ({:.1} graphs/s)",
            self.graphs,
            self.dynamic_graphs,
            self.runs,
            self.precision_runs.len(),
            self.op_coverage.len(),
            self.findings.len(),
            self.wall_seconds,
            self.graphs_per_sec()
        )
    }
}

/// Compile a prepared graph at `precision` with per-pass IR validation
/// forced on. The compile gate's own static verifier is disabled here: it
/// would fold static findings into a generic compile error, while the
/// campaign runs the verifier as its own zero-execution stage
/// ([`static_stage`]) so they surface as [`FindingKind::Static`].
fn compile_case(
    g: &Graph,
    precision: DType,
    seed: u64,
) -> crate::util::error::Result<(CompileSession, CompiledModel)> {
    let mut opts = CompileOptions {
        precision,
        verify_passes: true,
        static_verify: false,
        seed,
        ..CompileOptions::default()
    };
    if precision != DType::F32 {
        opts.calib_inputs = vec![simrun::synth_inputs(g, seed ^ 0x5eed)];
    }
    let mut sess = CompileSession::new(opts);
    let c = sess.compile(g)?;
    Ok((sess, c))
}

/// Zero-execution finding stage: run the static binary verifier over the
/// emitted program. `Some(detail)` when it reports an Error-level finding —
/// such a binary is rejected without simulating a single instruction.
pub fn static_stage(c: &CompiledModel) -> crate::util::error::Result<Option<String>> {
    let sr = crate::validate::validate_static(&c.asm, &c.plan, &c.mach)?;
    let errs: Vec<_> = sr.error_findings().collect();
    Ok(errs
        .first()
        .map(|first| format!("{} error findings, first: {}", errs.len(), first.line())))
}

/// Compile a prepared graph at `precision` (per-pass IR validation forced
/// on) and differentially verify the machine against the oracle.
pub fn compile_and_verify(
    g: &Graph,
    precision: DType,
    seed: u64,
) -> crate::util::error::Result<simrun::VerifyReport> {
    let (mut sess, c) = compile_case(g, precision, seed)?;
    sess.verify_auto(&c)
}

/// Run one (graph, precision) case, catching panics at the boundary.
/// `None` = passed; `Some((kind, detail))` = finding.
pub fn run_case(g: &Graph, precision: DType, seed: u64) -> Option<(FindingKind, String)> {
    type CaseResult = crate::util::error::Result<Option<(FindingKind, String)>>;
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> CaseResult {
        let (mut sess, c) = compile_case(g, precision, seed)?;
        if let Some(detail) = static_stage(&c)? {
            return Ok(Some((FindingKind::Static, detail)));
        }
        let rep = sess.verify_auto(&c)?;
        Ok(if rep.passed() {
            None
        } else {
            Some((FindingKind::Divergence, rep.summary()))
        })
    }));
    match res {
        Ok(Ok(outcome)) => outcome,
        Ok(Err(e)) => {
            let kind = match &e {
                Error::Trap(_) | Error::Sim(_) => FindingKind::SimError,
                _ => FindingKind::CompileError,
            };
            Some((kind, format!("{e}")))
        }
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "opaque panic payload".to_string()
            };
            Some((FindingKind::Panic, msg))
        }
    }
}

/// Failure signature used by the reducer: kind plus the error-class prefix
/// of the detail (the text before the first ':'), so shrinking is allowed
/// to change messages but not the failure class.
pub fn signature(kind: FindingKind, detail: &str) -> String {
    format!("{}|{}", kind.name(), detail.split(':').next().unwrap_or(""))
}

#[derive(Default)]
struct WorkerOut {
    graphs: usize,
    runs: usize,
    dynamic_graphs: usize,
    op_cov: BTreeMap<String, usize>,
    prec_runs: BTreeMap<String, usize>,
    findings: Vec<Finding>,
}

fn fuzz_one_seed(opts: &FuzzOptions, seed: u64, out: &mut WorkerOut) {
    let t = match gen::generate(seed, &opts.gen) {
        Ok(t) => t,
        Err(e) => {
            // The generator only emits graphs it believes are well-formed,
            // so a prepare failure here is itself a bug to report.
            out.findings.push(Finding {
                seed,
                precision: DType::F32,
                kind: FindingKind::CompileError,
                detail: format!("generate: {e}"),
                graph: Graph::new("generate_failed"),
                reduced: None,
            });
            return;
        }
    };
    out.graphs += 1;
    if t.dynamic {
        out.dynamic_graphs += 1;
    }
    for op in &t.ops {
        *out.op_cov.entry((*op).to_string()).or_insert(0) += 1;
    }
    for &p in &opts.precisions {
        out.runs += 1;
        *out.prec_runs.entry(p.name().to_string()).or_insert(0) += 1;
        if let Some((kind, detail)) = run_case(&t.graph, p, seed) {
            let reduced = if opts.reduce {
                let sig = signature(kind, &detail);
                let pred = |g: &Graph| match run_case(g, p, seed) {
                    Some((k, d)) => signature(k, &d) == sig,
                    None => false,
                };
                Some(reduce::reduce(&t.graph, pred).graph)
            } else {
                None
            };
            out.findings.push(Finding {
                seed,
                precision: p,
                kind,
                detail,
                graph: t.graph.clone(),
                reduced,
            });
        }
    }
}

/// Run a fuzz campaign. Deterministic for a given `FuzzOptions` (modulo
/// `wall_seconds`): seeds are index-striped across workers and merged in
/// seed order, so thread count and scheduling never change the report.
pub fn run_campaign(opts: &FuzzOptions) -> FuzzReport {
    let t0 = Instant::now();
    let nw = if opts.workers > 0 {
        opts.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    let nw = nw.clamp(1, (opts.seeds.max(1) as usize).min(64));
    let mut parts: Vec<WorkerOut> = Vec::with_capacity(nw);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..nw)
            .map(|w| {
                s.spawn(move || {
                    let mut out = WorkerOut::default();
                    let mut i = w as u64;
                    while i < opts.seeds {
                        fuzz_one_seed(opts, opts.start_seed + i, &mut out);
                        i += nw as u64;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("fuzz worker panicked"));
        }
    });
    let mut report = FuzzReport::default();
    for p in parts {
        report.graphs += p.graphs;
        report.runs += p.runs;
        report.dynamic_graphs += p.dynamic_graphs;
        for (k, v) in p.op_cov {
            *report.op_coverage.entry(k).or_insert(0) += v;
        }
        for (k, v) in p.prec_runs {
            *report.precision_runs.entry(k).or_insert(0) += v;
        }
        report.findings.extend(p.findings);
    }
    report
        .findings
        .sort_by(|a, b| (a.seed, a.precision.name()).cmp(&(b.seed, b.precision.name())));
    report.wall_seconds = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_campaign_is_clean_and_worker_invariant() {
        let base = FuzzOptions {
            seeds: 8,
            precisions: vec![DType::F32],
            workers: 1,
            ..FuzzOptions::default()
        };
        let a = run_campaign(&base);
        assert_eq!(a.graphs, 8);
        assert_eq!(a.runs, 8);
        for f in &a.findings {
            panic!("unexpected finding: {}", f.headline());
        }
        let b = run_campaign(&FuzzOptions { workers: 3, ..base });
        assert_eq!(a.graphs, b.graphs);
        assert_eq!(a.op_coverage, b.op_coverage);
        assert_eq!(a.findings.len(), b.findings.len());
    }

    #[test]
    fn quantized_campaign_is_clean() {
        let opts = FuzzOptions {
            seeds: 4,
            start_seed: 100,
            precisions: vec![DType::I8, DType::I4],
            ..FuzzOptions::default()
        };
        let r = run_campaign(&opts);
        assert_eq!(r.runs, 8);
        for f in &r.findings {
            panic!("unexpected finding: {}", f.headline());
        }
        assert_eq!(r.precision_runs.get("INT8"), Some(&4));
        assert_eq!(r.precision_runs.get("INT4"), Some(&4));
    }

    #[test]
    fn static_stage_is_clean_on_generated_graphs() {
        for seed in 0..3u64 {
            let t = gen::generate(seed, &GenConfig::default()).unwrap();
            let (_sess, c) = compile_case(&t.graph, DType::F32, seed).unwrap();
            assert_eq!(static_stage(&c).unwrap(), None, "seed {seed}");
        }
    }

    #[test]
    fn report_json_round_trips() {
        let opts = FuzzOptions {
            seeds: 3,
            precisions: vec![DType::F32],
            ..FuzzOptions::default()
        };
        let r = run_campaign(&opts);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("graphs").as_usize(), Some(3));
        assert_eq!(j.get("findings_count").as_usize(), Some(0));
        assert!(j.get("op_coverage").as_obj().is_some());
    }
}
