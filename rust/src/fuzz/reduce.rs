//! Delta-debugging reducer: greedily shrink a failing graph while the
//! failure keeps reproducing.
//!
//! Two shrink moves run to a fixed point:
//!
//! 1. **Node dropping** (last to first): remove a node; its outputs that are
//!    still consumed downstream become graph inputs (their inferred static
//!    shapes are kept, so the synthesized-input machinery still works), its
//!    exposed outputs leave the output list, and orphaned inputs/
//!    initializers are pruned.
//! 2. **Dimension halving**: halve one fixed extent of one graph input at a
//!    time. Candidates that no longer shape-check (e.g. a feature dim now
//!    disagreeing with a weight) are discarded before the predicate runs.
//!
//! Every candidate is re-`prepare`d — shape inference re-annotates all node
//! outputs, so stale annotations can never leak into a reduced graph — and
//! accepted only when `still_fails` says the *same* failure signature
//! reproduces. The caller's predicate therefore only ever sees structurally
//! valid graphs.

use std::collections::BTreeSet;

use crate::ir::{Dim, Graph, TensorId};

/// Outcome of one reduction: the smallest accepted graph plus effort stats.
#[derive(Debug, Clone)]
pub struct ReduceResult {
    pub graph: Graph,
    /// Fixed-point rounds executed.
    pub rounds: usize,
    /// Candidates handed to the predicate.
    pub candidates: usize,
}

const MAX_ROUNDS: usize = 8;

/// Remove node `i`, rewiring the graph so it stays well-formed. Returns the
/// prepared candidate, or `None` when the removal cannot produce a valid
/// graph (all outputs gone, a needed tensor has no static shape, ...).
fn drop_node(g: &Graph, i: usize) -> Option<Graph> {
    let mut c = g.clone();
    let node = c.nodes.remove(i);
    // Exposed outputs of the dropped node disappear from the interface.
    c.outputs.retain(|t| !node.outputs.contains(t));
    if c.outputs.is_empty() {
        return None;
    }
    // Outputs still consumed downstream get promoted to graph inputs; that
    // needs a static shape to synthesize data for.
    for out in &node.outputs {
        let consumed = c.nodes.iter().any(|n| n.inputs.contains(out));
        if consumed {
            let static_shape = c.tensors[out.0]
                .shape
                .as_ref()
                .map(|s| s.is_static())
                .unwrap_or(false);
            if !static_shape {
                return None;
            }
            c.inputs.push(*out);
        }
    }
    // Prune inputs and initializers nothing references any more.
    let used: BTreeSet<TensorId> = c
        .nodes
        .iter()
        .flat_map(|n| n.inputs.iter().copied())
        .collect();
    let out_set: BTreeSet<TensorId> = c.outputs.iter().copied().collect();
    c.inputs.retain(|t| used.contains(t) || out_set.contains(t));
    c.initializers.retain(|t, _| used.contains(t));
    crate::frontend::prepare(c).ok()
}

/// Halve dimension `di` of graph input `t`. Returns the prepared candidate
/// or `None` when the shrunken shape no longer infers.
fn halve_dim(g: &Graph, t: TensorId, di: usize) -> Option<Graph> {
    let mut c = g.clone();
    let mut shape = c.tensors[t.0].shape.clone()?;
    let n = match shape.0.get(di) {
        Some(Dim::Fixed(n)) if *n > 1 => *n,
        _ => return None,
    };
    shape.0[di] = Dim::Fixed(n / 2);
    c.tensors[t.0].shape = Some(shape);
    crate::frontend::prepare(c).ok()
}

/// Greedily shrink `graph` while `still_fails` keeps returning true. The
/// input graph must already fail; the result is the smallest graph found
/// that still reproduces the failure.
pub fn reduce<F: Fn(&Graph) -> bool>(graph: &Graph, still_fails: F) -> ReduceResult {
    let mut best = graph.clone();
    let mut candidates = 0usize;
    let mut rounds = 0usize;
    loop {
        rounds += 1;
        let mut improved = false;
        // Move 1: drop nodes, newest first (later nodes usually depend on
        // earlier ones, so this order unravels chains from the back).
        let mut i = best.nodes.len();
        while i > 0 {
            i -= 1;
            if best.nodes.len() <= 1 {
                break;
            }
            if let Some(cand) = drop_node(&best, i) {
                candidates += 1;
                if still_fails(&cand) {
                    best = cand;
                    improved = true;
                    i = best.nodes.len();
                }
            }
        }
        // Move 2: halve input extents one (input, dim) at a time.
        let mut shrunk = true;
        while shrunk {
            shrunk = false;
            for idx in 0..best.inputs.len() {
                let t = best.inputs[idx];
                let rank = match &best.tensors[t.0].shape {
                    Some(s) => s.rank(),
                    None => 0,
                };
                for di in 0..rank {
                    if let Some(cand) = halve_dim(&best, t, di) {
                        candidates += 1;
                        if still_fails(&cand) {
                            best = cand;
                            improved = true;
                            shrunk = true;
                        }
                    }
                }
            }
        }
        if !improved || rounds >= MAX_ROUNDS {
            break;
        }
    }
    ReduceResult { graph: best, rounds, candidates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{model_zoo, prepare};
    use crate::ir::OpKind;

    /// Predicate: "graph still contains a Softmax" — a stand-in for "the
    /// failure reproduces" that lets the reducer strip everything else.
    fn has_softmax(g: &Graph) -> bool {
        g.nodes.iter().any(|n| n.op == OpKind::Softmax)
    }

    #[test]
    fn reduces_chain_to_single_guilty_node() {
        let mut g = model_zoo::mlp(&[8, 16, 16, 4], 4);
        let last = *g.outputs.last().unwrap();
        let sm = g.node(OpKind::Softmax, "sm", &[last], Default::default());
        g.outputs = vec![sm];
        let g = prepare(g).unwrap();
        assert!(g.nodes.len() >= 6);

        let r = reduce(&g, has_softmax);
        assert!(has_softmax(&r.graph), "reduction lost the failure");
        assert!(
            r.graph.nodes.len() <= 2,
            "expected <=2 nodes, got {}",
            r.graph.nodes.len()
        );
        assert!(r.graph.check().is_ok());
        // The Softmax input was promoted to a graph input with a static
        // shape, and batch was halved 4 -> 1.
        let x = r.graph.inputs[0];
        let dims = r.graph.tensors[x.0].shape.as_ref().unwrap().dims();
        assert_eq!(dims[0], 1, "batch not minimized: {dims:?}");
    }

    #[test]
    fn reduction_prunes_unused_initializers() {
        let g = prepare(model_zoo::mlp(&[8, 16, 16, 4], 2)).unwrap();
        let n_inits = g.initializers.len();
        let r = reduce(&g, |c| c.nodes.iter().any(|n| n.op == OpKind::Gemm));
        assert!(r.graph.initializers.len() < n_inits);
        assert_eq!(
            r.graph.nodes.iter().filter(|n| n.op == OpKind::Gemm).count(),
            1,
            "should keep exactly one Gemm"
        );
    }

    #[test]
    fn non_reducible_graph_survives_unchanged() {
        let g = prepare(model_zoo::mlp(&[4, 2], 1)).unwrap();
        // Predicate holds only for the exact original node count, so every
        // candidate is rejected.
        let n = g.nodes.len();
        let r = reduce(&g, |c| c.nodes.len() == n);
        assert_eq!(r.graph.nodes.len(), n);
    }
}
