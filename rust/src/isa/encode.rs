//! Binary instruction encoding — real RISC-V formats (R/I/S/B/U/J), the
//! F-extension layouts, and the RVV encodings (OP-V major opcode, unit-stride
//! vector loads/stores). `decode` inverts this exactly; the round-trip is
//! property-tested and is part of ISA validation (contribution 3).

use crate::isa::{Instr, Op};
use crate::util::error::{Error, Result};

/// Instruction formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    R,
    R4,
    I,
    S,
    B,
    U,
    J,
    VSetF,
    VMem,
    VArith,
}

/// Format of each opcode.
pub fn format_of(op: Op) -> Format {
    use Op::*;
    match op {
        Lui | Auipc => Format::U,
        Jal => Format::J,
        Jalr | Lw | Flw | Addi | Slti | Andi | Ori | Xori | Slli | Srli | Srai => Format::I,
        Beq | Bne | Blt | Bge => Format::B,
        Sw | Fsw => Format::S,
        FmaddS => Format::R4,
        Vsetvli => Format::VSetF,
        Vle32 | Vse32 | Vle8 | Vse8 => Format::VMem,
        VaddVV | VsubVV | VmulVV | VmaccVV | VfaddVV | VfsubVV | VfmulVV | VfmaccVV
        | VfmaccVF | VfredsumVS | VfmaxVV | VfmvVF => Format::VArith,
        _ => Format::R,
    }
}

// Major opcodes.
const OP_LUI: u32 = 0b0110111;
const OP_AUIPC: u32 = 0b0010111;
const OP_JAL: u32 = 0b1101111;
const OP_JALR: u32 = 0b1100111;
const OP_BRANCH: u32 = 0b1100011;
const OP_LOAD: u32 = 0b0000011;
const OP_STORE: u32 = 0b0100011;
const OP_IMM: u32 = 0b0010011;
const OP_OP: u32 = 0b0110011;
const OP_LOAD_FP: u32 = 0b0000111;
const OP_STORE_FP: u32 = 0b0100111;
const OP_FP: u32 = 0b1010011;
const OP_FMADD: u32 = 0b1000011;
const OP_V: u32 = 0b1010111;

/// (funct3, funct7-or-imm-tag) per opcode where applicable.
fn rfunct(op: Op) -> (u32, u32) {
    use Op::*;
    match op {
        Add => (0b000, 0),
        Sub => (0b000, 0b0100000),
        Sll => (0b001, 0),
        Slt => (0b010, 0),
        Xor => (0b100, 0),
        Srl => (0b101, 0),
        Sra => (0b101, 0b0100000),
        Or => (0b110, 0),
        And => (0b111, 0),
        Mul => (0b000, 1),
        Mulh => (0b001, 1),
        Div => (0b100, 1),
        Rem => (0b110, 1),
        // F ext (funct7 carries the operation).
        FaddS => (0b000, 0b0000000),
        FsubS => (0b000, 0b0000100),
        FmulS => (0b000, 0b0001000),
        FdivS => (0b000, 0b0001100),
        FminS => (0b000, 0b0010100),
        FmaxS => (0b001, 0b0010100),
        FcvtWS => (0b000, 0b1100000),
        FcvtSW => (0b000, 0b1101000),
        // Custom-0 space for the two transcendental helpers.
        FexpS => (0b000, 0b1111100),
        FrsqrtS => (0b001, 0b1111100),
        _ => (0, 0),
    }
}

fn ifunct(op: Op) -> u32 {
    use Op::*;
    match op {
        Addi => 0b000,
        Slti => 0b010,
        Xori => 0b100,
        Ori => 0b110,
        Andi => 0b111,
        Slli => 0b001,
        Srli | Srai => 0b101,
        Jalr | Lw => 0b010,
        Flw => 0b010,
        _ => 0,
    }
}

fn bfunct(op: Op) -> u32 {
    use Op::*;
    match op {
        Beq => 0b000,
        Bne => 0b001,
        Blt => 0b100,
        Bge => 0b101,
        _ => unreachable!(),
    }
}

/// funct6 codes for the RVV arithmetic subset (vm bit always 1 = unmasked).
fn vfunct6(op: Op) -> (u32, u32) {
    // (funct6, funct3): funct3 000=OPIVV, 001=OPFVV, 101=OPFVF, 010=OPMVV.
    use Op::*;
    match op {
        VaddVV => (0b000000, 0b000),
        VsubVV => (0b000010, 0b000),
        VmulVV => (0b100101, 0b010),
        VmaccVV => (0b101101, 0b010),
        VfaddVV => (0b000000, 0b001),
        VfsubVV => (0b000010, 0b001),
        VfmulVV => (0b100100, 0b001),
        VfmaccVV => (0b101100, 0b001),
        VfmaccVF => (0b101100, 0b101),
        VfredsumVS => (0b000001, 0b001),
        VfmaxVV => (0b000110, 0b001),
        VfmvVF => (0b010111, 0b101),
        _ => unreachable!(),
    }
}

/// Immediate range check per format. Part of ISA validation.
pub fn check_imm(i: &Instr) -> Result<()> {
    let ok = match format_of(i.op) {
        Format::I => {
            if matches!(i.op, Op::Slli | Op::Srli | Op::Srai) {
                (0..32).contains(&i.imm)
            } else {
                (-2048..=2047).contains(&i.imm)
            }
        }
        Format::S => (-2048..=2047).contains(&i.imm),
        Format::B => (-4096..=4094).contains(&i.imm) && i.imm % 2 == 0,
        Format::U => (0..=0xFFFFF).contains(&i.imm),
        Format::J => (-(1 << 20)..(1 << 20)).contains(&i.imm) && i.imm % 2 == 0,
        Format::VSetF => (0..=31).contains(&i.imm) && i.rs3 <= 3, // AVL imm unused; LMUL in rs3
        _ => true,
    };
    if ok {
        Ok(())
    } else {
        Err(Error::Validation(format!(
            "immediate {} out of range for {}",
            i.imm,
            i.op.mnemonic()
        )))
    }
}

fn check_regs(i: &Instr) -> Result<()> {
    for (r, name) in [(i.rd, "rd"), (i.rs1, "rs1"), (i.rs2, "rs2"), (i.rs3, "rs3")] {
        if r >= 32 && format_of(i.op) != Format::VSetF {
            return Err(Error::Validation(format!(
                "{name}={r} out of range for {}",
                i.op.mnemonic()
            )));
        }
    }
    Ok(())
}

/// Encode one instruction to its 32-bit word.
pub fn encode(i: &Instr) -> Result<u32> {
    check_regs(i)?;
    check_imm(i)?;
    let (rd, rs1, rs2, rs3) = (i.rd as u32, i.rs1 as u32, i.rs2 as u32, i.rs3 as u32);
    let imm = i.imm;
    use Op::*;
    let word = match format_of(i.op) {
        Format::U => {
            let opc = if i.op == Lui { OP_LUI } else { OP_AUIPC };
            ((imm as u32) << 12) | (rd << 7) | opc
        }
        Format::J => {
            let v = imm as u32;
            let imm20 = (v >> 20) & 1;
            let imm10_1 = (v >> 1) & 0x3FF;
            let imm11 = (v >> 11) & 1;
            let imm19_12 = (v >> 12) & 0xFF;
            (imm20 << 31)
                | (imm10_1 << 21)
                | (imm11 << 20)
                | (imm19_12 << 12)
                | (rd << 7)
                | OP_JAL
        }
        Format::I => {
            let opc = match i.op {
                Jalr => OP_JALR,
                Lw => OP_LOAD,
                Flw => OP_LOAD_FP,
                _ => OP_IMM,
            };
            let mut hi = (imm as u32) & 0xFFF;
            if i.op == Srai {
                hi |= 0b0100000 << 5;
            }
            (hi << 20) | (rs1 << 15) | (ifunct(i.op) << 12) | (rd << 7) | opc
        }
        Format::S => {
            let opc = if i.op == Fsw { OP_STORE_FP } else { OP_STORE };
            let v = imm as u32;
            let funct3 = 0b010;
            (((v >> 5) & 0x7F) << 25)
                | (rs2 << 20)
                | (rs1 << 15)
                | (funct3 << 12)
                | ((v & 0x1F) << 7)
                | opc
        }
        Format::B => {
            let v = imm as u32;
            let imm12 = (v >> 12) & 1;
            let imm10_5 = (v >> 5) & 0x3F;
            let imm4_1 = (v >> 1) & 0xF;
            let imm11 = (v >> 11) & 1;
            (imm12 << 31)
                | (imm10_5 << 25)
                | (rs2 << 20)
                | (rs1 << 15)
                | (bfunct(i.op) << 12)
                | (imm4_1 << 8)
                | (imm11 << 7)
                | OP_BRANCH
        }
        Format::R => {
            let (f3, f7) = rfunct(i.op);
            let opc = match i.op.class() {
                crate::isa::OpClass::FAlu
                | crate::isa::OpClass::FMul
                | crate::isa::OpClass::FDiv
                | crate::isa::OpClass::FCustom => OP_FP,
                _ => OP_OP,
            };
            (f7 << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | opc
        }
        Format::R4 => {
            (rs3 << 27) | (rs2 << 20) | (rs1 << 15) | (rd << 7) | OP_FMADD
        }
        Format::VSetF => {
            // vsetvli rd, rs1, e32,m<2^rs3>: zimm[10:0] = vtype.
            let vtype = (0b010 << 3) | rs3; // sew=32 (code 010), lmul in low bits
            (vtype << 20) | (rs1 << 15) | (0b111 << 12) | (rd << 7) | OP_V
        }
        Format::VMem => {
            let opc = if matches!(i.op, Vle32 | Vle8) { OP_LOAD_FP } else { OP_STORE_FP };
            let width = if matches!(i.op, Vle32 | Vse32) { 0b110 } else { 0b000 };
            // mop=00 unit-stride, vm=1, lumop=0.
            (1u32 << 25) | (rs1 << 15) | ((width as u32) << 12) | (rd << 7) | opc
        }
        Format::VArith => {
            let (f6, f3) = vfunct6(i.op);
            // vd | funct3 | vs1/rs1 | vs2 | vm=1 | funct6 | OP-V
            (f6 << 26) | (1 << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | OP_V
        }
    };
    Ok(word)
}

/// Encode a full program.
pub fn encode_all(prog: &[Instr]) -> Result<Vec<u32>> {
    prog.iter().map(encode).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_rv32i_encodings() {
        // addi t0, zero, 42 -> imm=42, rs1=0, f3=0, rd=5, 0010011
        assert_eq!(
            encode(&Instr::i(Op::Addi, 5, 0, 42)).unwrap(),
            (42 << 20) | (5 << 7) | 0b0010011
        );
        // add a0, a1, a2
        assert_eq!(
            encode(&Instr::r(Op::Add, 10, 11, 12)).unwrap(),
            (12 << 20) | (11 << 15) | (10 << 7) | 0b0110011
        );
        // lui t0, 0x12345
        assert_eq!(
            encode(&Instr::u(Op::Lui, 5, 0x12345)).unwrap(),
            (0x12345 << 12) | (5 << 7) | 0b0110111
        );
    }

    #[test]
    fn imm_range_enforced() {
        assert!(encode(&Instr::i(Op::Addi, 1, 0, 2047)).is_ok());
        assert!(encode(&Instr::i(Op::Addi, 1, 0, 2048)).is_err());
        assert!(encode(&Instr::i(Op::Addi, 1, 0, -2048)).is_ok());
        assert!(encode(&Instr::i(Op::Addi, 1, 0, -2049)).is_err());
        assert!(encode(&Instr::b(Op::Beq, 1, 2, 3)).is_err()); // odd branch target
        assert!(encode(&Instr::b(Op::Beq, 1, 2, 4)).is_ok());
    }

    #[test]
    fn distinct_words_for_distinct_ops() {
        // Same operands, different opcodes must encode differently.
        let mut seen = std::collections::BTreeSet::new();
        for op in Op::all() {
            let i = Instr { op: *op, rd: 1, rs1: 2, rs2: 3, rs3: 1, imm: 4 };
            let w = encode(&i).unwrap();
            assert!(seen.insert(w), "collision on {}", op.mnemonic());
        }
    }
}
