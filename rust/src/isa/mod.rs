//! The accelerator's 61-instruction ISA (paper §3.6: "the target hardware's
//! 61-instruction ISA").
//!
//! The paper never lists its ISA, so this is *our* definition (DESIGN.md
//! §Known deviations): a RV32I integer subset + RV32M multiply + RV32F
//! single-float subset + two custom scalar ops (FEXP.S for
//! softmax/gelu-class kernels, FRSQRT.S for normalization) + an RVV vector
//! subset sized for NN inference. Exactly 61 instructions — enforced by
//! test.
//!
//! Submodules: [`encode`] (binary encoding), [`decode`] (the inverse),
//! [`regs`] (register file naming).

pub mod decode;
pub mod encode;
pub mod regs;

/// Operation class for timing/energy models and scheduler latency lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    Alu,
    Mul,
    Div,
    Branch,
    Jump,
    Load,
    Store,
    FAlu,
    FMul,
    FDiv,
    FMa,
    FCustom,
    VSet,
    VLoad,
    VStore,
    VAlu,
    VMul,
    VFma,
    VRed,
}

impl OpClass {
    /// Number of distinct classes — sizes the simulator's fixed per-class
    /// counter arrays (no map lookups on the execution hot path).
    pub const COUNT: usize = 19;

    /// Every class, in declaration (= index) order.
    pub const ALL: [OpClass; OpClass::COUNT] = [
        OpClass::Alu,
        OpClass::Mul,
        OpClass::Div,
        OpClass::Branch,
        OpClass::Jump,
        OpClass::Load,
        OpClass::Store,
        OpClass::FAlu,
        OpClass::FMul,
        OpClass::FDiv,
        OpClass::FMa,
        OpClass::FCustom,
        OpClass::VSet,
        OpClass::VLoad,
        OpClass::VStore,
        OpClass::VAlu,
        OpClass::VMul,
        OpClass::VFma,
        OpClass::VRed,
    ];

    /// Dense index into `[_; OpClass::COUNT]` counter arrays.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable lowercase name (RunStats keys, bench tables, energy reports).
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Alu => "alu",
            OpClass::Mul => "mul",
            OpClass::Div => "div",
            OpClass::Branch => "branch",
            OpClass::Jump => "jump",
            OpClass::Load => "load",
            OpClass::Store => "store",
            OpClass::FAlu => "falu",
            OpClass::FMul => "fmul",
            OpClass::FDiv => "fdiv",
            OpClass::FMa => "fma",
            OpClass::FCustom => "fcustom",
            OpClass::VSet => "vset",
            OpClass::VLoad => "vload",
            OpClass::VStore => "vstore",
            OpClass::VAlu => "valu",
            OpClass::VMul => "vmul",
            OpClass::VFma => "vfma",
            OpClass::VRed => "vred",
        }
    }
}

macro_rules! isa {
    ($($variant:ident => ($name:literal, $class:ident)),+ $(,)?) => {
        /// The 61 opcodes.
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub enum Op { $($variant),+ }

        impl Op {
            pub fn mnemonic(self) -> &'static str {
                match self { $(Op::$variant => $name),+ }
            }
            pub fn class(self) -> OpClass {
                match self { $(Op::$variant => OpClass::$class),+ }
            }
            pub fn all() -> &'static [Op] {
                &[ $(Op::$variant),+ ]
            }
        }
    };
}

isa! {
    // -- RV32I base (27) ----------------------------------------------------
    Lui => ("lui", Alu),
    Auipc => ("auipc", Alu),
    Jal => ("jal", Jump),
    Jalr => ("jalr", Jump),
    Beq => ("beq", Branch),
    Bne => ("bne", Branch),
    Blt => ("blt", Branch),
    Bge => ("bge", Branch),
    Lw => ("lw", Load),
    Sw => ("sw", Store),
    Addi => ("addi", Alu),
    Slti => ("slti", Alu),
    Andi => ("andi", Alu),
    Ori => ("ori", Alu),
    Xori => ("xori", Alu),
    Slli => ("slli", Alu),
    Srli => ("srli", Alu),
    Srai => ("srai", Alu),
    Add => ("add", Alu),
    Sub => ("sub", Alu),
    Sll => ("sll", Alu),
    Srl => ("srl", Alu),
    Sra => ("sra", Alu),
    And => ("and", Alu),
    Or => ("or", Alu),
    Xor => ("xor", Alu),
    Slt => ("slt", Alu),
    // -- RV32M (4) ------------------------------------------------------------
    Mul => ("mul", Mul),
    Mulh => ("mulh", Mul),
    Div => ("div", Div),
    Rem => ("rem", Div),
    // -- RV32F subset (11) -------------------------------------------------------
    Flw => ("flw", Load),
    Fsw => ("fsw", Store),
    FaddS => ("fadd.s", FAlu),
    FsubS => ("fsub.s", FAlu),
    FmulS => ("fmul.s", FMul),
    FdivS => ("fdiv.s", FDiv),
    FmaddS => ("fmadd.s", FMa),
    FminS => ("fmin.s", FAlu),
    FmaxS => ("fmax.s", FAlu),
    FcvtWS => ("fcvt.w.s", FAlu),
    FcvtSW => ("fcvt.s.w", FAlu),
    // -- Custom scalar (2): transcendental support for softmax/gelu/norm ----------
    FexpS => ("fexp.s", FCustom),
    FrsqrtS => ("frsqrt.s", FCustom),
    // -- RVV subset (17) --------------------------------------------------------
    Vsetvli => ("vsetvli", VSet),
    Vle32 => ("vle32.v", VLoad),
    Vse32 => ("vse32.v", VStore),
    Vle8 => ("vle8.v", VLoad),
    Vse8 => ("vse8.v", VStore),
    VaddVV => ("vadd.vv", VAlu),
    VsubVV => ("vsub.vv", VAlu),
    VmulVV => ("vmul.vv", VMul),
    VmaccVV => ("vmacc.vv", VFma),
    VfaddVV => ("vfadd.vv", VAlu),
    VfsubVV => ("vfsub.vv", VAlu),
    VfmulVV => ("vfmul.vv", VMul),
    VfmaccVV => ("vfmacc.vv", VFma),
    VfmaccVF => ("vfmacc.vf", VFma),
    VfredsumVS => ("vfredsum.vs", VRed),
    VfmaxVV => ("vfmax.vv", VAlu),
    VfmvVF => ("vfmv.v.f", VAlu),
}

/// One instruction: opcode + operand fields. Field meaning depends on the
/// format of `op` (see `encode`); unused fields are zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub rd: u8,
    pub rs1: u8,
    pub rs2: u8,
    /// Third source (fmadd) / LMUL field (vsetvli).
    pub rs3: u8,
    pub imm: i32,
}

impl Instr {
    pub fn new(op: Op) -> Instr {
        Instr { op, rd: 0, rs1: 0, rs2: 0, rs3: 0, imm: 0 }
    }

    pub fn r(op: Op, rd: u8, rs1: u8, rs2: u8) -> Instr {
        Instr { op, rd, rs1, rs2, rs3: 0, imm: 0 }
    }

    pub fn i(op: Op, rd: u8, rs1: u8, imm: i32) -> Instr {
        Instr { op, rd, rs1, rs2: 0, rs3: 0, imm }
    }

    pub fn s(op: Op, rs1: u8, rs2: u8, imm: i32) -> Instr {
        Instr { op, rd: 0, rs1, rs2, rs3: 0, imm }
    }

    pub fn b(op: Op, rs1: u8, rs2: u8, imm: i32) -> Instr {
        Instr { op, rd: 0, rs1, rs2, rs3: 0, imm }
    }

    pub fn u(op: Op, rd: u8, imm: i32) -> Instr {
        Instr { op, rd, rs1: 0, rs2: 0, rs3: 0, imm }
    }

    pub fn r4(op: Op, rd: u8, rs1: u8, rs2: u8, rs3: u8) -> Instr {
        Instr { op, rd, rs1, rs2, rs3, imm: 0 }
    }

    /// Assembly text rendering.
    pub fn asm(&self) -> String {
        use encode::Format::*;
        let r = regs::xname;
        let f = regs::fname;
        let v = regs::vname;
        match encode::format_of(self.op) {
            R => {
                let (a, b, c) = reg_names(self.op, self.rd, self.rs1, self.rs2);
                format!("{} {a}, {b}, {c}", self.op.mnemonic())
            }
            R4 => format!(
                "{} {}, {}, {}, {}",
                self.op.mnemonic(),
                f(self.rd),
                f(self.rs1),
                f(self.rs2),
                f(self.rs3)
            ),
            I => match self.op {
                Op::Jalr => format!("jalr {}, {}({})", r(self.rd), self.imm, r(self.rs1)),
                Op::Lw => format!("lw {}, {}({})", r(self.rd), self.imm, r(self.rs1)),
                Op::Flw => format!("flw {}, {}({})", f(self.rd), self.imm, r(self.rs1)),
                _ => format!("{} {}, {}, {}", self.op.mnemonic(), r(self.rd), r(self.rs1), self.imm),
            },
            S => match self.op {
                Op::Fsw => format!("fsw {}, {}({})", f(self.rs2), self.imm, r(self.rs1)),
                _ => format!("sw {}, {}({})", r(self.rs2), self.imm, r(self.rs1)),
            },
            B => format!(
                "{} {}, {}, {}",
                self.op.mnemonic(),
                r(self.rs1),
                r(self.rs2),
                self.imm
            ),
            U | J => format!("{} {}, {}", self.op.mnemonic(), r(self.rd), self.imm),
            VSetF => format!(
                "vsetvli {}, {}, e32, m{}",
                r(self.rd),
                r(self.rs1),
                1 << self.rs3
            ),
            VMem => format!("{} {}, ({})", self.op.mnemonic(), v(self.rd), r(self.rs1)),
            VArith => match self.op {
                Op::VfmaccVF => format!(
                    "vfmacc.vf {}, {}, {}",
                    v(self.rd),
                    f(self.rs1),
                    v(self.rs2)
                ),
                Op::VfmvVF => format!("vfmv.v.f {}, {}", v(self.rd), f(self.rs1)),
                _ => format!(
                    "{} {}, {}, {}",
                    self.op.mnemonic(),
                    v(self.rd),
                    v(self.rs1),
                    v(self.rs2)
                ),
            },
        }
    }
}

fn reg_names(op: Op, rd: u8, rs1: u8, rs2: u8) -> (String, String, String) {
    use OpClass::*;
    match op.class() {
        FAlu | FMul | FDiv | FCustom => {
            // fcvt mixes files; keep it simple: fcvt.w.s rd=x, rs=f.
            if op == Op::FcvtWS {
                (regs::xname(rd), regs::fname(rs1), regs::fname(rs2))
            } else if op == Op::FcvtSW {
                (regs::fname(rd), regs::xname(rs1), regs::xname(rs2))
            } else {
                (regs::fname(rd), regs::fname(rs1), regs::fname(rs2))
            }
        }
        _ => (regs::xname(rd), regs::xname(rs1), regs::xname(rs2)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_61_instructions() {
        // The paper's "61-instruction ISA" — pinned.
        assert_eq!(Op::all().len(), 61);
    }

    #[test]
    fn mnemonics_unique() {
        let set: std::collections::BTreeSet<_> =
            Op::all().iter().map(|o| o.mnemonic()).collect();
        assert_eq!(set.len(), 61);
    }

    #[test]
    fn asm_rendering_samples() {
        assert_eq!(Instr::i(Op::Addi, 5, 0, 42).asm(), "addi t0, zero, 42");
        assert_eq!(Instr::i(Op::Lw, 10, 2, 16).asm(), "lw a0, 16(sp)");
        assert_eq!(
            Instr::r(Op::FaddS, 1, 2, 3).asm(),
            "fadd.s ft1, ft2, ft3"
        );
        assert_eq!(
            Instr::r(Op::VfmaccVV, 2, 3, 4).asm(),
            "vfmacc.vv v2, v3, v4"
        );
    }

    #[test]
    fn classes_cover_all_ops() {
        for op in Op::all() {
            let _ = op.class(); // no panic, exhaustive by construction
        }
        assert_eq!(Op::VfmaccVV.class(), OpClass::VFma);
        assert_eq!(Op::Lw.class(), OpClass::Load);
        assert_eq!(Op::FexpS.class(), OpClass::FCustom);
    }

    #[test]
    fn class_indices_are_dense_and_names_unique() {
        assert_eq!(OpClass::ALL.len(), OpClass::COUNT);
        for (i, c) in OpClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "{:?}", c);
            // Exhaustiveness guard: adding an OpClass variant without
            // extending ALL/COUNT makes this wildcard-free match (and so
            // the whole crate) fail to compile.
            match c {
                OpClass::Alu
                | OpClass::Mul
                | OpClass::Div
                | OpClass::Branch
                | OpClass::Jump
                | OpClass::Load
                | OpClass::Store
                | OpClass::FAlu
                | OpClass::FMul
                | OpClass::FDiv
                | OpClass::FMa
                | OpClass::FCustom
                | OpClass::VSet
                | OpClass::VLoad
                | OpClass::VStore
                | OpClass::VAlu
                | OpClass::VMul
                | OpClass::VFma
                | OpClass::VRed => {}
            }
        }
        let names: std::collections::BTreeSet<_> =
            OpClass::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), OpClass::COUNT);
    }
}
