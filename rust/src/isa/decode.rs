//! Instruction decoding — exact inverse of [`super::encode`].
//!
//! The functional simulator exercises the *binary* encoding end-to-end
//! through here, and the encode∘decode = id property test doubles as
//! encoding validation. Since the pre-decoded fast path landed, the
//! simulator calls this once per program word at predecode time
//! ([`crate::sim::predecode`]) rather than once per retired instruction —
//! only the naive reference loop (`Machine::run_reference`) still decodes
//! on every fetch.

use crate::isa::{Instr, Op};
use crate::util::error::{Error, Result};

#[inline]
fn sext(v: u32, bits: u32) -> i32 {
    let shift = 32 - bits;
    ((v << shift) as i32) >> shift
}

/// Decode one 32-bit word.
pub fn decode(word: u32) -> Result<Instr> {
    let opc = word & 0x7F;
    let rd = ((word >> 7) & 0x1F) as u8;
    let f3 = (word >> 12) & 0x7;
    let rs1 = ((word >> 15) & 0x1F) as u8;
    let rs2 = ((word >> 20) & 0x1F) as u8;
    let f7 = (word >> 25) & 0x7F;
    use Op::*;
    let instr = match opc {
        0b0110111 => Instr::u(Lui, rd, ((word >> 12) & 0xFFFFF) as i32),
        0b0010111 => Instr::u(Auipc, rd, ((word >> 12) & 0xFFFFF) as i32),
        0b1101111 => {
            let imm20 = (word >> 31) & 1;
            let imm10_1 = (word >> 21) & 0x3FF;
            let imm11 = (word >> 20) & 1;
            let imm19_12 = (word >> 12) & 0xFF;
            let v = (imm20 << 20) | (imm19_12 << 12) | (imm11 << 11) | (imm10_1 << 1);
            Instr::u(Jal, rd, sext(v, 21))
        }
        0b1100111 => Instr::i(Jalr, rd, rs1, sext(word >> 20, 12)),
        0b1100011 => {
            let imm12 = (word >> 31) & 1;
            let imm10_5 = (word >> 25) & 0x3F;
            let imm4_1 = (word >> 8) & 0xF;
            let imm11 = (word >> 7) & 1;
            let v = (imm12 << 12) | (imm11 << 11) | (imm10_5 << 5) | (imm4_1 << 1);
            let op = match f3 {
                0b000 => Beq,
                0b001 => Bne,
                0b100 => Blt,
                0b101 => Bge,
                _ => return Err(bad(word, "branch funct3")),
            };
            Instr::b(op, rs1, rs2, sext(v, 13))
        }
        0b0000011 => Instr::i(Lw, rd, rs1, sext(word >> 20, 12)),
        0b0100011 => {
            let v = ((word >> 25) << 5) | ((word >> 7) & 0x1F);
            Instr::s(Sw, rs1, rs2, sext(v & 0xFFF, 12))
        }
        0b0010011 => {
            let imm = sext(word >> 20, 12);
            let op = match f3 {
                0b000 => Addi,
                0b010 => Slti,
                0b100 => Xori,
                0b110 => Ori,
                0b111 => Andi,
                0b001 => Slli,
                0b101 => {
                    if f7 == 0b0100000 {
                        Srai
                    } else {
                        Srli
                    }
                }
                _ => return Err(bad(word, "op-imm funct3")),
            };
            let imm = if matches!(op, Slli | Srli | Srai) { imm & 0x1F } else { imm };
            Instr::i(op, rd, rs1, imm)
        }
        0b0110011 => {
            let op = match (f3, f7) {
                (0b000, 0) => Add,
                (0b000, 0b0100000) => Sub,
                (0b001, 0) => Sll,
                (0b010, 0) => Slt,
                (0b100, 0) => Xor,
                (0b101, 0) => Srl,
                (0b101, 0b0100000) => Sra,
                (0b110, 0) => Or,
                (0b111, 0) => And,
                (0b000, 1) => Mul,
                (0b001, 1) => Mulh,
                (0b100, 1) => Div,
                (0b110, 1) => Rem,
                _ => return Err(bad(word, "op funct")),
            };
            Instr::r(op, rd, rs1, rs2)
        }
        0b0000111 => {
            // flw vs vector load: real RVV disambiguates by width funct3 —
            // scalar flw is 010, vector unit-stride loads are 000 (8-bit
            // elements) / 110 (32-bit elements).
            if f3 == 0b010 {
                Instr::i(Flw, rd, rs1, sext(word >> 20, 12))
            } else {
                let op = if f3 == 0b110 { Vle32 } else { Vle8 };
                let mut i = Instr::new(op);
                i.rd = rd;
                i.rs1 = rs1;
                i
            }
        }
        0b0100111 => {
            if f3 == 0b010 {
                let v = ((word >> 25) << 5) | ((word >> 7) & 0x1F);
                Instr::s(Fsw, rs1, rs2, sext(v & 0xFFF, 12))
            } else {
                let op = if f3 == 0b110 { Vse32 } else { Vse8 };
                let mut i = Instr::new(op);
                i.rd = rd;
                i.rs1 = rs1;
                i
            }
        }
        0b1000011 => Instr::r4(FmaddS, rd, rs1, rs2, ((word >> 27) & 0x1F) as u8),
        0b1010011 => {
            let op = match (f7, f3) {
                (0b0000000, 0b000) => FaddS,
                (0b0000100, 0b000) => FsubS,
                (0b0001000, 0b000) => FmulS,
                (0b0001100, 0b000) => FdivS,
                (0b0010100, 0b000) => FminS,
                (0b0010100, 0b001) => FmaxS,
                (0b1100000, 0b000) => FcvtWS,
                (0b1101000, 0b000) => FcvtSW,
                (0b1111100, 0b000) => FexpS,
                (0b1111100, 0b001) => FrsqrtS,
                _ => return Err(bad(word, "fp funct")),
            };
            Instr::r(op, rd, rs1, rs2)
        }
        0b1010111 => {
            if f3 == 0b111 {
                // vsetvli
                let vtype = word >> 20;
                let lmul = (vtype & 0x7) as u8;
                let mut i = Instr::new(Vsetvli);
                i.rd = rd;
                i.rs1 = rs1;
                i.rs3 = lmul;
                i
            } else {
                let f6 = word >> 26;
                let op = match (f6, f3) {
                    (0b000000, 0b000) => VaddVV,
                    (0b000010, 0b000) => VsubVV,
                    (0b100101, 0b010) => VmulVV,
                    (0b101101, 0b010) => VmaccVV,
                    (0b000000, 0b001) => VfaddVV,
                    (0b000010, 0b001) => VfsubVV,
                    (0b100100, 0b001) => VfmulVV,
                    (0b101100, 0b001) => VfmaccVV,
                    (0b101100, 0b101) => VfmaccVF,
                    (0b000001, 0b001) => VfredsumVS,
                    (0b000110, 0b001) => VfmaxVV,
                    (0b010111, 0b101) => VfmvVF,
                    _ => return Err(bad(word, "vector funct")),
                };
                Instr::r(op, rd, rs1, rs2)
            }
        }
        _ => return Err(bad(word, "major opcode")),
    };
    Ok(instr)
}

#[cold]
fn bad(word: u32, what: &str) -> Error {
    Error::Validation(format!("illegal instruction {word:#010x}: bad {what}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::{encode, format_of, Format};
    use crate::util::proptest::forall;

    /// Fields that survive a round-trip for each format (unused fields are
    /// normalized to zero by decode).
    fn normalize(mut i: Instr) -> Instr {
        match format_of(i.op) {
            Format::U | Format::J => {
                i.rs1 = 0;
                i.rs2 = 0;
                i.rs3 = 0;
            }
            Format::I => {
                i.rs2 = 0;
                i.rs3 = 0;
            }
            Format::S | Format::B => {
                i.rd = 0;
                i.rs3 = 0;
            }
            Format::R => {
                i.imm = 0;
                i.rs3 = 0;
            }
            Format::R4 => i.imm = 0,
            Format::VSetF => {
                i.rs2 = 0;
                i.imm = 0;
            }
            Format::VMem => {
                i.rs2 = 0;
                i.rs3 = 0;
                i.imm = 0;
            }
            Format::VArith => {
                i.rs3 = 0;
                i.imm = 0;
            }
        }
        i
    }

    #[test]
    fn roundtrip_every_opcode() {
        for op in Op::all() {
            let i = normalize(Instr { op: *op, rd: 3, rs1: 4, rs2: 5, rs3: 2, imm: 8 });
            let w = encode(&i).unwrap();
            let d = decode(w).unwrap();
            assert_eq!(d, i, "{}", op.mnemonic());
        }
    }

    #[test]
    fn property_roundtrip_random_instructions() {
        forall("encode/decode roundtrip", 2000, |rng| {
            let op = *rng.choose(Op::all());
            let imm = match format_of(op) {
                Format::I => {
                    if matches!(op, Op::Slli | Op::Srli | Op::Srai) {
                        rng.range(0, 32) as i32
                    } else {
                        rng.range(-2048, 2048) as i32
                    }
                }
                Format::S => rng.range(-2048, 2048) as i32,
                Format::B => (rng.range(-2048, 2047) * 2) as i32,
                Format::U => rng.range(0, 0x100000) as i32,
                Format::J => (rng.range(-(1 << 19), 1 << 19) * 2) as i32,
                _ => 0,
            };
            let i = normalize(Instr {
                op,
                rd: rng.range(0, 32) as u8,
                rs1: rng.range(0, 32) as u8,
                rs2: rng.range(0, 32) as u8,
                rs3: if format_of(op) == Format::VSetF {
                    rng.range(0, 4) as u8
                } else {
                    rng.range(0, 32) as u8
                },
                imm,
            });
            let w = encode(&i).map_err(|e| format!("encode {e}"))?;
            let d = decode(w).map_err(|e| format!("decode {e}"))?;
            if d == i {
                Ok(())
            } else {
                Err(format!("{:?} -> {w:#x} -> {:?}", i, d))
            }
        });
    }

    #[test]
    fn rejects_garbage_words() {
        assert!(decode(0xFFFF_FFFF).is_err());
        assert!(decode(0x0000_0000).is_err()); // opcode 0 illegal
    }
}
