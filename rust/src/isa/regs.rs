//! Register file model: 32 integer (x), 32 float (f), 32 vector (v)
//! registers, with RISC-V ABI names for the scalar files.

/// Number of registers in each file.
pub const NUM_X: usize = 32;
pub const NUM_F: usize = 32;
pub const NUM_V: usize = 32;

/// ABI names for integer registers.
pub const X_NAMES: [&str; 32] = [
    "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1",
    "a2", "a3", "a4", "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7",
    "s8", "s9", "s10", "s11", "t3", "t4", "t5", "t6",
];

pub fn xname(r: u8) -> String {
    X_NAMES.get(r as usize).map(|s| s.to_string()).unwrap_or(format!("x?{r}"))
}

pub fn fname(r: u8) -> String {
    format!("ft{r}")
}

pub fn vname(r: u8) -> String {
    format!("v{r}")
}

// Conventional roles used by codegen (documented calling convention for
// generated kernels; the register allocator respects these).
/// Hard zero.
pub const ZERO: u8 = 0;
/// Stack pointer.
pub const SP: u8 = 2;
/// Kernel argument registers (base addresses, extents): a0-a7.
pub const ARG0: u8 = 10;
pub const ARG1: u8 = 11;
pub const ARG2: u8 = 12;
pub const ARG3: u8 = 13;
pub const ARG4: u8 = 14;
pub const ARG5: u8 = 15;
/// Scratch (t0-t6 = x5..x7, x28..x31).
pub const T0: u8 = 5;
pub const T1: u8 = 6;
pub const T2: u8 = 7;
pub const T3: u8 = 28;
pub const T4: u8 = 29;
pub const T5: u8 = 30;
pub const T6: u8 = 31;
/// Callee-saved loop counters (s2-s11 = x18..x27).
pub const S2: u8 = 18;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abi_names() {
        assert_eq!(xname(0), "zero");
        assert_eq!(xname(2), "sp");
        assert_eq!(xname(10), "a0");
        assert_eq!(xname(31), "t6");
    }

    #[test]
    fn roles_are_valid_registers() {
        for r in [ZERO, SP, ARG0, ARG5, T0, T6, S2] {
            assert!((r as usize) < NUM_X);
        }
    }
}
